"""repro — tunable precision emulation via automatic BLAS offloading.

JAX/Pallas reproduction of "A Pilot Study on Tunable Precision
Emulation via Automatic BLAS Offloading" (arXiv:2503.22875).

Package map:
  * ``repro.core``      — Ozaki INT8 split-GEMM engine, precision
    policies, and the automatic dot_general interceptor;
  * ``repro.kernels``   — Pallas TPU kernels (interpret-mode on CPU);
  * ``repro.apps``      — paper workloads (MuST Green's-function
    contour study);
  * ``repro.analysis``  — roofline analysis of dry-run artifacts;
  * ``repro.configs``   — frozen LM run configurations (presets);
  * ``repro.models``    — llama-style decoder LM (scanned blocks,
    KV-cache prefill/decode programs);
  * ``repro.train``     — AdamW, deterministic synthetic data, atomic
    bit-exact checkpointing;
  * ``repro.launch``    — the resume-aware training loop (``--backend``
    routes the whole step through the offload transform);
  * ``repro.serve``     — continuous-batching greedy inference engine.
"""

__version__ = "0.1.0"
