"""repro — tunable precision emulation via automatic BLAS offloading.

JAX/Pallas reproduction of "A Pilot Study on Tunable Precision
Emulation via Automatic BLAS Offloading" (arXiv:2503.22875).

Package map:
  * ``repro.core``      — Ozaki INT8 split-GEMM engine, precision
    policies, and the automatic dot_general interceptor;
  * ``repro.kernels``   — Pallas TPU kernels (interpret-mode on CPU);
  * ``repro.apps``      — paper workloads (MuST Green's-function
    contour study);
  * ``repro.analysis``  — roofline analysis of dry-run artifacts.
"""

__version__ = "0.1.0"
