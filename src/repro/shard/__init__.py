"""Mesh construction and data-parallel sharding helpers.

The scaling axis the ROADMAP calls for: everything multi-device in the
repo goes through this module, so the mesh recipe is written down once.
On a CPU-only box JAX exposes *virtual* devices via::

    XLA_FLAGS=--xla_force_host_platform_device_count=8

which is exactly how the sharded tests, the multi-device CI job, and
the ``--mesh dp=8`` train smoke run — same code path as real
accelerators, no hardware required.

Helpers:

* :func:`parse_mesh_spec` / :func:`build_mesh` — ``"dp=8"`` (or
  ``"dp=4,tp=2"``) to a :class:`jax.sharding.Mesh` over the first
  ``prod(sizes)`` devices;
* :func:`data_parallel_sharding` — the canonical DP placement:
  parameters (and optimizer state) replicated, the batch split on its
  leading axis;
* :func:`replicate` / :func:`shard_batch` — ``device_put`` shortcuts
  for those two placements.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "parse_mesh_spec",
    "build_mesh",
    "data_parallel_sharding",
    "data_parallel_setup",
    "replicate",
    "shard_batch",
]


def parse_mesh_spec(spec: str) -> Dict[str, int]:
    """``"dp=8"`` / ``"dp=4,tp=2"`` -> ``{"dp": 8}`` / ``{"dp": 4, "tp": 2}``.

    Axis order in the string is the mesh axis order.  Sizes must be
    positive integers; axis names must be unique.
    """
    axes: Dict[str, int] = {}
    for part in (spec or "").split(","):
        name, sep, size = part.strip().partition("=")
        if not sep or not name:
            raise ValueError(
                f"bad mesh spec {spec!r}: expected 'axis=size[,...]' "
                "(e.g. 'dp=8')")
        if name in axes:
            raise ValueError(f"bad mesh spec {spec!r}: duplicate axis "
                             f"{name!r}")
        try:
            n = int(size)
        except ValueError:
            raise ValueError(f"bad mesh spec {spec!r}: size of "
                             f"{name!r} is not an integer") from None
        if n < 1:
            raise ValueError(f"bad mesh spec {spec!r}: size of "
                             f"{name!r} must be >= 1")
        axes[name] = n
    return axes


def build_mesh(spec: str = "dp=1", devices=None) -> Mesh:
    """Build a :class:`Mesh` from a spec string.

    Uses the first ``prod(sizes)`` of ``devices`` (default
    ``jax.devices()``), reshaped to the spec's axis sizes.  Raises with
    the virtual-device recipe when the host has too few devices.
    """
    axes = parse_mesh_spec(spec)
    devices = list(jax.devices()) if devices is None else list(devices)
    need = math.prod(axes.values())
    if need > len(devices):
        raise ValueError(
            f"mesh {spec!r} needs {need} devices but only "
            f"{len(devices)} are visible; on CPU set "
            "XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need} before the first jax import")
    grid = np.array(devices[:need]).reshape(tuple(axes.values()))
    return Mesh(grid, tuple(axes))


def data_parallel_sharding(mesh: Mesh, axis: str | None = None
                           ) -> Tuple[NamedSharding, NamedSharding]:
    """The canonical data-parallel placement for ``(params, batch)``.

    Returns ``(replicated, batch_sharding)``: parameters/optimizer
    state fully replicated, the batch partitioned over ``axis``
    (default: the mesh's first axis) on its leading dimension.  Both
    are :class:`NamedSharding` and apply to whole pytrees via
    ``jax.device_put(tree, sharding)``.
    """
    axis = axis or mesh.axis_names[0]
    if axis not in mesh.axis_names:
        raise ValueError(f"axis {axis!r} not in mesh axes "
                         f"{mesh.axis_names}")
    return (NamedSharding(mesh, PartitionSpec()),
            NamedSharding(mesh, PartitionSpec(axis)))


def data_parallel_setup(spec: str, global_batch: int, state=None):
    """The CLI recipe: mesh + divisibility guard + replicated state.

    Builds the mesh from ``spec``, verifies ``global_batch`` divides
    by the mesh size (a ragged shard would change per-shard loss
    weighting), replicates ``state`` (any pytree, e.g.
    ``(params, opt_state)``) across it, and returns
    ``(mesh, batch_sharding, state)``.  Shared by the train and tune
    entry points so the data-parallel bring-up is written down once.

    Raises ``SystemExit`` (these are CLI drivers) with the virtual-
    device-friendly message on a non-dividing batch.
    """
    mesh = build_mesh(spec)
    if global_batch % mesh.size:
        raise SystemExit(
            f"global batch {global_batch} is not divisible by mesh "
            f"size {mesh.size} ({spec!r}); pass one (with a batch "
            "that divides) or drop the mesh")
    replicated, batch_sharding = data_parallel_sharding(mesh)
    if state is not None:
        state = jax.device_put(state, replicated)
    return mesh, batch_sharding, state


def replicate(tree, mesh: Mesh):
    """Place every leaf of ``tree`` replicated across ``mesh``."""
    return jax.device_put(tree, NamedSharding(mesh, PartitionSpec()))


def shard_batch(batch, mesh: Mesh, axis: str | None = None):
    """Split ``batch`` over ``axis`` on its leading dimension.

    The leading extent must divide by the axis size — a ragged final
    shard would change per-shard loss weighting, breaking the
    dp=N == single-device equivalence the tests assert.
    """
    axis = axis or mesh.axis_names[0]
    size = mesh.shape[axis]
    lead = jax.tree_util.tree_leaves(batch)[0].shape[0]
    if lead % size:
        raise ValueError(
            f"leading batch extent {lead} is not divisible by mesh "
            f"axis {axis!r} of size {size}")
    return jax.device_put(
        batch, NamedSharding(mesh, PartitionSpec(axis)))
