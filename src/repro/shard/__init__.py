"""Mesh construction and data-parallel sharding helpers.

The scaling axis the ROADMAP calls for: everything multi-device in the
repo goes through this module, so the mesh recipe is written down once.
On a CPU-only box JAX exposes *virtual* devices via::

    XLA_FLAGS=--xla_force_host_platform_device_count=8

which is exactly how the sharded tests, the multi-device CI job, and
the ``--mesh dp=8`` train smoke run — same code path as real
accelerators, no hardware required.

Helpers:

* :func:`parse_mesh_spec` / :func:`build_mesh` — ``"dp=8"`` (or
  ``"dp=4,tp=2"``) to a :class:`jax.sharding.Mesh` over the first
  ``prod(sizes)`` devices;
* :func:`data_parallel_sharding` — the canonical DP placement:
  parameters (and optimizer state) replicated, the batch split on its
  leading axis;
* :func:`train_mesh_setup` — the 2-D (``dp``×``tp``) bring-up for the
  train/tune CLIs: axis names validated against :data:`TRAIN_AXES`,
  batch divisibility checked against the ``dp`` extent, and the train
  state placed per the LM axis rules (:mod:`repro.shard.rules` — tp
  splits attention heads and the SwiGLU hidden dim, everything else
  replicated);
* :mod:`repro.shard.collectives` — bucketed / ``ppermute``-pipelined
  gradient all-reduce for the sharded train step;
* :func:`replicate` / :func:`shard_batch` — ``device_put`` shortcuts.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.shard.collectives import (DEFAULT_BUCKET_BYTES,
                                     GRAD_REDUCE_MODES, bucket_stats,
                                     bucketed_psum, reduce_gradients,
                                     ring_all_reduce)
from repro.shard.rules import (DP_AXIS, TP_AXIS, TRAIN_AXES,
                               lm_param_specs, rules_to_specs,
                               specs_to_rules, state_shardings,
                               train_state_specs, validate_tp)

__all__ = [
    "parse_mesh_spec",
    "build_mesh",
    "data_parallel_sharding",
    "data_parallel_setup",
    "train_mesh_setup",
    "replicate",
    "shard_batch",
    # repro.shard.rules
    "DP_AXIS", "TP_AXIS", "TRAIN_AXES", "validate_tp",
    "lm_param_specs", "train_state_specs", "specs_to_rules",
    "rules_to_specs", "state_shardings",
    # repro.shard.collectives
    "DEFAULT_BUCKET_BYTES", "GRAD_REDUCE_MODES", "bucket_stats",
    "bucketed_psum", "reduce_gradients", "ring_all_reduce",
]


def parse_mesh_spec(spec: str) -> Dict[str, int]:
    """``"dp=8"`` / ``"dp=4,tp=2"`` -> ``{"dp": 8}`` / ``{"dp": 4, "tp": 2}``.

    Axis order in the string is the mesh axis order.  Sizes must be
    positive integers; axis names must be unique.
    """
    axes: Dict[str, int] = {}
    for part in (spec or "").split(","):
        name, sep, size = part.strip().partition("=")
        if not sep or not name:
            raise ValueError(
                f"bad mesh spec {spec!r}: expected 'axis=size[,...]' "
                "(e.g. 'dp=8')")
        if name in axes:
            raise ValueError(f"bad mesh spec {spec!r}: duplicate axis "
                             f"{name!r}")
        try:
            n = int(size)
        except ValueError:
            raise ValueError(f"bad mesh spec {spec!r}: size of "
                             f"{name!r} is not an integer") from None
        if n < 1:
            raise ValueError(f"bad mesh spec {spec!r}: size of "
                             f"{name!r} must be >= 1")
        axes[name] = n
    return axes


def build_mesh(spec: str = "dp=1", devices=None) -> Mesh:
    """Build a :class:`Mesh` from a spec string.

    Uses the first ``prod(sizes)`` of ``devices`` (default
    ``jax.devices()``), reshaped to the spec's axis sizes.  Raises with
    the virtual-device recipe when the host has too few devices.
    """
    axes = parse_mesh_spec(spec)
    devices = list(jax.devices()) if devices is None else list(devices)
    need = math.prod(axes.values())
    if need > len(devices):
        raise ValueError(
            f"mesh {spec!r} needs {need} devices but only "
            f"{len(devices)} are visible; on CPU set "
            "XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need} before the first jax import")
    grid = np.array(devices[:need]).reshape(tuple(axes.values()))
    return Mesh(grid, tuple(axes))


def data_parallel_sharding(mesh: Mesh, axis: str | None = None
                           ) -> Tuple[NamedSharding, NamedSharding]:
    """The canonical data-parallel placement for ``(params, batch)``.

    Returns ``(replicated, batch_sharding)``: parameters/optimizer
    state fully replicated, the batch partitioned over ``axis``
    (default: the mesh's first axis) on its leading dimension.  Both
    are :class:`NamedSharding` and apply to whole pytrees via
    ``jax.device_put(tree, sharding)``.
    """
    axis = axis or mesh.axis_names[0]
    if axis not in mesh.axis_names:
        raise ValueError(f"axis {axis!r} not in mesh axes "
                         f"{mesh.axis_names}")
    return (NamedSharding(mesh, PartitionSpec()),
            NamedSharding(mesh, PartitionSpec(axis)))


def data_parallel_setup(spec: str, global_batch: int, state=None):
    """The CLI recipe: mesh + divisibility guard + replicated state.

    Builds the mesh from ``spec``, verifies ``global_batch`` divides
    by the mesh size (a ragged shard would change per-shard loss
    weighting), replicates ``state`` (any pytree, e.g.
    ``(params, opt_state)``) across it, and returns
    ``(mesh, batch_sharding, state)``.  Shared by the train and tune
    entry points so the data-parallel bring-up is written down once.

    Raises ``SystemExit`` (these are CLI drivers) with the virtual-
    device-friendly message on a non-dividing batch.
    """
    mesh = build_mesh(spec)
    if global_batch % mesh.size:
        raise SystemExit(
            f"global batch {global_batch} is not divisible by mesh "
            f"size {mesh.size} ({spec!r}); pass one (with a batch "
            "that divides) or drop the mesh")
    replicated, batch_sharding = data_parallel_sharding(mesh)
    if state is not None:
        state = jax.device_put(state, replicated)
    return mesh, batch_sharding, state


def train_mesh_setup(spec: str, global_batch: int, cfg=None,
                     state=None):
    """2-D ``dp``×``tp`` mesh bring-up for the train/tune CLIs.

    Validates everything that used to fail deep inside ``shard_map``
    tracing *up front*, with CLI-grade messages:

    * axis names must come from :data:`TRAIN_AXES` (``dp`` = data
      parallel over the batch, ``tp`` = tensor parallel over attention
      heads / the SwiGLU hidden dim);
    * ``dp*tp`` must fit the visible device count (via
      :func:`build_mesh`, which prints the virtual-device recipe);
    * ``global_batch`` must divide by the ``dp`` extent — *not* the
      mesh size: tp shards all see the same batch slice;
    * with ``tp > 1``, the tp degree must divide the LM config's head
      and hidden extents (:func:`repro.shard.rules.validate_tp`).

    The mesh is always built dp-major (``("dp", "tp")``) regardless of
    the order in ``spec``, so adjacent devices form a tp group.
    ``state = (params, opt_state)``, when given, is placed per the LM
    axis rules: tp-sharded projections, everything else replicated.

    Returns ``(mesh, batch_sharding, state, state_specs)`` where
    ``state_specs`` is the ``(params, opt_state)`` PartitionSpec
    pytree (also what the sharded checkpoint manifest records).

    Raises ``SystemExit`` (these are CLI drivers) on bad specs.
    """
    try:
        axes = parse_mesh_spec(spec)
    except ValueError as e:
        raise SystemExit(f"[shard] {e}") from None
    unknown = [a for a in axes if a not in TRAIN_AXES]
    if unknown:
        raise SystemExit(
            f"[shard] mesh {spec!r}: unknown axis name(s) "
            f"{', '.join(repr(a) for a in unknown)}; valid axes are "
            f"'{DP_AXIS}' (data parallel, splits the batch) and "
            f"'{TP_AXIS}' (tensor parallel, splits attention heads "
            "and the MLP hidden dim), e.g. --mesh dp=4,tp=2")
    dp = axes.get(DP_AXIS, 1)
    tp = axes.get(TP_AXIS, 1)
    canonical = f"{DP_AXIS}={dp},{TP_AXIS}={tp}"
    try:
        mesh = build_mesh(canonical)
    except ValueError as e:
        # build_mesh validates dp*tp <= len(jax.devices()) and its
        # message carries the XLA_FLAGS recipe; surface it before any
        # tracing starts.
        raise SystemExit(f"[shard] {e}") from None
    if global_batch % dp:
        raise SystemExit(
            f"[shard] global batch {global_batch} is not divisible by "
            f"the data-parallel extent dp={dp} ({spec!r}); tensor "
            "parallelism does not split the batch, so only dp counts")
    if tp > 1:
        if cfg is None:
            raise SystemExit(f"[shard] mesh {spec!r} has tp={tp} but "
                             "no model config to derive axis rules")
        try:
            validate_tp(cfg, tp)
        except ValueError as e:
            raise SystemExit(f"[shard] {e}") from None
    state_specs = (train_state_specs(cfg) if tp > 1 and cfg is not None
                   else (None if cfg is None else jax.tree_util.tree_map(
                       lambda _: PartitionSpec(),
                       train_state_specs(cfg),
                       is_leaf=lambda x: isinstance(x, PartitionSpec))))
    if state is not None:
        if state_specs is not None:
            state = jax.device_put(
                state, state_shardings(mesh, state_specs))
        else:
            state = replicate(state, mesh)
    batch_sharding = NamedSharding(mesh, PartitionSpec(DP_AXIS))
    return mesh, batch_sharding, state, state_specs


def replicate(tree, mesh: Mesh):
    """Place every leaf of ``tree`` replicated across ``mesh``."""
    return jax.device_put(tree, NamedSharding(mesh, PartitionSpec()))


def shard_batch(batch, mesh: Mesh, axis: str | None = None):
    """Split ``batch`` over ``axis`` on its leading dimension.

    The leading extent must divide by the axis size — a ragged final
    shard would change per-shard loss weighting, breaking the
    dp=N == single-device equivalence the tests assert.
    """
    axis = axis or mesh.axis_names[0]
    size = mesh.shape[axis]
    lead = jax.tree_util.tree_leaves(batch)[0].shape[0]
    if lead % size:
        raise ValueError(
            f"leading batch extent {lead} is not divisible by mesh "
            f"axis {axis!r} of size {size}")
    return jax.device_put(
        batch, NamedSharding(mesh, PartitionSpec(axis)))
