"""Per-parameter sharding rules for the LM pytree on a dp×tp mesh.

One table, used by the train step (shard_map in/out specs), the serve
engine (tp-sharded ``device_put``), and the sharded checkpoint writer
(axis rules in the layout manifest):

=============  =======================  ===========================
parameter      spec                     meaning
=============  =======================  ===========================
wq, wk, wv     P(None, None, "tp")      column-parallel: each shard
                                        holds ``num_heads/tp`` query
                                        (``num_kv_heads/tp`` kv) heads
w_gate, w_up   P(None, None, "tp")      column-parallel: ``d_ff/tp``
                                        hidden columns per shard
wo, w_down     P(None, "tp", None)      row-parallel: contracts over
                                        the shard's local columns,
                                        completed by a tp ``psum``
embed, norms,  P()                      replicated (their gradients
lm_head                                 are completed by the
                                        identity-fwd/psum-bwd wrapper
                                        in :mod:`repro.models.lm`)
=============  =======================  ===========================

The ``dp`` axis never appears in parameter specs — parameters are
replicated across data-parallel replicas and only the batch is split
on ``dp``.
"""

from __future__ import annotations

from typing import List, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = ["DP_AXIS", "TP_AXIS", "TRAIN_AXES", "validate_tp",
           "lm_param_specs", "train_state_specs", "specs_to_rules",
           "rules_to_specs", "state_shardings"]

#: The two mesh axes the training stack understands.
DP_AXIS = "dp"
TP_AXIS = "tp"
TRAIN_AXES = (DP_AXIS, TP_AXIS)


def validate_tp(cfg, tp: int) -> None:
    """Raise if a tp degree cannot shard this LM config evenly.

    Column-parallel attention shards whole heads and row-parallel MLP
    shards hidden columns, so ``tp`` must divide ``num_heads``,
    ``num_kv_heads`` and ``d_ff``.
    """
    bad = [f"{k}={v}" for k, v in (("num_heads", cfg.num_heads),
                                   ("num_kv_heads", cfg.num_kv_heads),
                                   ("d_ff", cfg.d_ff)) if v % tp]
    if bad:
        raise ValueError(
            f"tp={tp} cannot shard config {cfg.name!r}: it must "
            f"divide " + ", ".join(bad))


def lm_param_specs(cfg, tp_axis: str = TP_AXIS) -> dict:
    """PartitionSpec pytree matching ``Model.init_params`` output."""
    P = PartitionSpec
    col = P(None, None, tp_axis)   # (L, d, out): split output columns
    row = P(None, tp_axis, None)   # (L, in, d): split input rows
    specs = {
        "embed": P(),
        "blocks": {
            "attn_norm": P(), "mlp_norm": P(),
            "wq": col, "wk": col, "wv": col, "wo": row,
            "w_gate": col, "w_up": col, "w_down": row,
        },
        "final_norm": P(),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P()
    return specs


def train_state_specs(cfg, tp_axis: str = TP_AXIS) -> tuple:
    """Specs for the full ``(params, opt_state)`` train state.

    AdamW moments mirror the parameter layout leaf for leaf; the step
    counter is a replicated scalar.
    """
    p = lm_param_specs(cfg, tp_axis)
    return p, {"step": PartitionSpec(), "mu": p, "nu": p}


def specs_to_rules(specs_tree, state_tree) -> List[List[Optional[str]]]:
    """Flatten a spec pytree to per-leaf axis-rule lists.

    Each leaf's rule is a list as long as its rank, entries either an
    axis name or ``None`` — the JSON-friendly form the checkpoint
    manifest records.
    """
    specs = jax.tree_util.tree_leaves(
        specs_tree, is_leaf=lambda x: isinstance(x, PartitionSpec))
    leaves = jax.tree_util.tree_leaves(state_tree)
    if len(specs) != len(leaves):
        raise ValueError(f"{len(specs)} specs for {len(leaves)} leaves")
    rules = []
    for spec, leaf in zip(specs, leaves):
        ents = list(spec) + [None] * (leaf.ndim - len(spec))
        rule = []
        for e in ents:
            if e is not None and not isinstance(e, str):
                raise ValueError(f"unsupported spec entry {e!r} "
                                 "(nested tuples) in checkpoint rules")
            rule.append(e)
        rules.append(rule)
    return rules


def rules_to_specs(rules) -> List[PartitionSpec]:
    """Inverse of :func:`specs_to_rules` (per-leaf, flat)."""
    return [PartitionSpec(*rule) for rule in rules]


def state_shardings(mesh: Mesh, specs_tree):
    """Spec pytree -> NamedSharding pytree for ``device_put``."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec))
