"""Gradient all-reduce strategies for the sharded train step.

The naive post-backward reduction — one ``pmean`` per gradient leaf —
serializes a long tail of small collectives after the whole backward
pass.  The strategies here keep the math bit-identical (``pmean`` *is*
``psum`` followed by division by the axis size) while giving XLA room
to overlap communication with the remaining backward GEMMs:

* :func:`bucketed_psum` — the default.  Gradient leaves are greedily
  grouped into byte-size buckets in flatten order and each bucket is
  reduced with a *single* multi-operand ``psum``, so the collective
  for an early bucket can be issued while later gradients are still
  being computed, and small leaves (norms) amortize launch overhead.
* :func:`ring_all_reduce` — a ``ppermute``-pipelined reduce behind the
  ``--grad-reduce ppermute`` flag.  N-1 neighbor hops accumulate the
  sum around the ring; per-shard accumulation *order* differs, so
  replicas agree only to rounding — it trades the bit-identity
  guarantee for point-to-point traffic, which is why it is opt-in.

:func:`bucket_stats` reports the bucketing a tree would get (bucket
count, bytes per ``psum``) — the ``bench_train_2d`` benchmark row and
the train-loop telemetry both record it.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import numpy as np

__all__ = ["DEFAULT_BUCKET_BYTES", "bucket_indices", "bucket_stats",
           "bucketed_psum", "ring_all_reduce", "reduce_gradients",
           "GRAD_REDUCE_MODES"]

#: Default gradient bucket size (4 MiB).  Big enough that projection
#: matrices of the small presets land in one collective each, small
#: enough that a multi-layer model produces several buckets to overlap.
DEFAULT_BUCKET_BYTES = 4 << 20

GRAD_REDUCE_MODES = ("bucketed", "blocking", "ppermute")


def _nbytes(leaf) -> int:
    return int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize


def bucket_indices(leaves, bucket_bytes: int) -> List[List[int]]:
    """Greedy, order-preserving bucketing of flat leaves by byte size.

    A leaf larger than ``bucket_bytes`` gets a bucket of its own; the
    bucket boundary is never allowed to split a leaf.
    """
    buckets: List[List[int]] = []
    cur: List[int] = []
    cur_bytes = 0
    for i, leaf in enumerate(leaves):
        nb = _nbytes(leaf)
        if cur and cur_bytes + nb > bucket_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += nb
    if cur:
        buckets.append(cur)
    return buckets


def bucket_stats(tree, bucket_bytes: int = DEFAULT_BUCKET_BYTES
                 ) -> Tuple[int, List[int]]:
    """``(bucket_count, bytes_per_psum)`` for ``tree``'s leaves."""
    leaves = jax.tree_util.tree_leaves(tree)
    buckets = bucket_indices(leaves, bucket_bytes)
    return len(buckets), [sum(_nbytes(leaves[i]) for i in idx)
                          for idx in buckets]


def bucketed_psum(tree, axis: str,
                  bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                  mean_size: int | None = None):
    """Reduce ``tree`` over ``axis`` with one fused psum per bucket.

    With ``mean_size`` the result is divided by it afterwards — the
    exact op sequence ``lax.pmean`` lowers to, so a bucketed mean is
    bit-identical to the per-leaf ``pmean`` it replaces.  Buckets are
    issued in flatten order without a barrier between them, so XLA's
    scheduler can start early buckets while later gradients are still
    in flight.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out = [None] * len(leaves)
    for idx in bucket_indices(leaves, bucket_bytes):
        reduced = jax.lax.psum(tuple(leaves[i] for i in idx), axis)
        for i, r in zip(idx, reduced):
            out[i] = r / mean_size if mean_size else r
    return jax.tree_util.tree_unflatten(treedef, out)


def ring_all_reduce(tree, axis: str, axis_size: int,
                    mean: bool = False):
    """``ppermute``-pipelined ring reduction over ``axis``.

    Every leaf takes ``axis_size - 1`` neighbor hops; hop ``j`` of one
    leaf can overlap hop ``j+1`` of another, trading one big collective
    for a pipeline of point-to-point transfers.  Each shard accumulates
    contributions in its own ring order, so replicas of the result
    agree only to floating-point rounding — callers that need
    bit-identical replicas use :func:`bucketed_psum` instead.
    """
    if axis_size < 1:
        raise ValueError(f"axis_size must be >= 1, got {axis_size}")
    perm = [(j, (j + 1) % axis_size) for j in range(axis_size)]

    def ring(x):
        acc = x
        for _ in range(axis_size - 1):
            x = jax.lax.ppermute(x, axis, perm)
            acc = acc + x
        return acc / axis_size if mean else acc

    return jax.tree_util.tree_map(ring, tree)


def reduce_gradients(grads, axis: str, axis_size: int,
                     mode: str = "bucketed",
                     bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """Mean-reduce a gradient pytree over the data-parallel axis.

    ``mode``: ``bucketed`` (default, overlappable, bit-identical to
    per-leaf pmean), ``blocking`` (an optimization barrier forces the
    whole backward to finish, then a single full-tree psum runs — the
    serialization the bucketed path exists to avoid; kept as the
    ``bench_train_2d`` reference), or ``ppermute`` (ring pipeline,
    replicas agree to rounding only).
    """
    if mode == "bucketed":
        return bucketed_psum(grads, axis, bucket_bytes,
                             mean_size=axis_size)
    if mode == "blocking":
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        leaves = jax.lax.optimization_barrier(tuple(leaves))
        reduced = jax.lax.psum(tuple(leaves), axis)
        return jax.tree_util.tree_unflatten(
            treedef, [r / axis_size for r in reduced])
    if mode == "ppermute":
        return ring_all_reduce(grads, axis, axis_size, mean=True)
    raise ValueError(f"unknown gradient-reduce mode {mode!r}; "
                     f"expected one of {GRAD_REDUCE_MODES}")
