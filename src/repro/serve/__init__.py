"""repro.serve — continuous-batching inference on the KV-cache programs."""

from .engine import Engine, Request

__all__ = ["Engine", "Request"]
