"""repro.serve — layered continuous-batching inference.

Layers (each importable on its own):

- :mod:`repro.serve.scheduler` — admission queue, request validation,
  slot assignment (FIFO / EDF).
- :mod:`repro.serve.kvcache` — KV layout managers: paged block tables
  over a shared pool, or the dense per-slot rectangle.
- :mod:`repro.serve.runner` — device execution: packed chunked-prefill
  waves interleaved with masked decode ticks.
- :mod:`repro.serve.engine` — the facade tying them together behind
  the original ``Engine.run(requests)`` API.
"""

from .engine import Engine
from .kvcache import DenseKVCache, PagedKVCache
from .scheduler import Request, SamplingParamError, Scheduler

__all__ = ["Engine", "Request", "SamplingParamError", "Scheduler",
           "PagedKVCache", "DenseKVCache"]
