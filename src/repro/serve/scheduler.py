"""Admission scheduling: request validation, queueing, slot assignment.

The scheduler owns the request queue and nothing else — it never sees
tokens or caches.  Admission hands out ``(slot, request)`` pairs
against the free slots and the KV manager's reservation check, so a
request is only admitted when its worst-case cache growth is already
booked (no decode-time deadlock).

Policies:

``fifo`` (default)
    Strict submission order, head-of-line blocking: if the oldest
    request cannot be placed (no slot, or no blocks for its worst
    case), nothing younger overtakes it.  This is exactly the ordering
    the pre-refactor engine had, which is why it is the default.

``edf``
    Earliest deadline first over ``t_enqueue + latency_target_s``
    (requests without a target sort last, FIFO among themselves).
    Still head-of-line blocking per the chosen order, so a starved
    urgent request blocks rather than being skipped forever.

Validation happens at submission with :class:`SamplingParamError` (a
``ValueError``), so a malformed request is rejected by name before it
ever costs a prefill.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

__all__ = ["Request", "SamplingParamError", "Scheduler"]


class SamplingParamError(ValueError):
    """A request's admission/sampling parameters are out of range."""


@dataclasses.dataclass
class Request:
    """One generation request; ``out`` fills as the engine decodes.

    ``temperature=0`` (the default) is greedy decoding — the engine's
    token-identity guarantees apply to it.  ``temperature > 0`` samples
    from the softmax at that temperature using a per-request
    deterministic stream seeded by ``seed`` (same request, same model,
    same tokens — regardless of batch neighbours).
    ``latency_target_s`` is the admission scheduler's deadline input
    (EDF policy) and is recorded against realized TTFT either way.
    """

    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0
    latency_target_s: Optional[float] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def validate_request(req: Request, max_len: int) -> None:
    """Raise :class:`SamplingParamError` for out-of-range parameters.

    The message texts for the pre-existing checks are part of the
    public behavior (tests match on them); SamplingParamError subclasses
    ValueError so older callers' ``except ValueError`` still works.
    """
    if not req.prompt:
        raise SamplingParamError("empty prompt")
    if req.max_new_tokens < 1:
        raise SamplingParamError(
            "max_new_tokens must be >= 1 (the engine always decodes "
            "the prompt's continuation)")
    if len(req.prompt) + req.max_new_tokens > max_len:
        raise SamplingParamError(
            f"prompt({len(req.prompt)}) + max_new_tokens"
            f"({req.max_new_tokens}) exceeds max_len={max_len}")
    if not (req.temperature >= 0.0):
        raise SamplingParamError(
            f"temperature must be >= 0 (0 = greedy), got "
            f"{req.temperature}")
    if req.temperature > 0 and not isinstance(req.seed, int):
        raise SamplingParamError(
            f"seed must be an int for sampled (temperature > 0) "
            f"requests, got {type(req.seed).__name__}")
    if req.latency_target_s is not None and not (
            req.latency_target_s > 0):
        raise SamplingParamError(
            f"latency_target_s must be > 0 (or None), got "
            f"{req.latency_target_s}")


class Scheduler:
    """Admission queue with pluggable ordering policy."""

    POLICIES = ("fifo", "edf")

    def __init__(self, max_len: int, policy: str = "fifo",
                 metrics=None, slo=None):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown scheduler policy {policy!r}; "
                             f"have {self.POLICIES}")
        self.max_len = int(max_len)
        self.policy = policy
        self.metrics = metrics
        # Optional repro.obs.SLOTracker: under edf, admitting a request
        # whose deadline already lapsed in the queue is reported as a
        # late admission (the violation is certain before prefill).
        self.slo = slo
        self._queue: List[Request] = []
        self._t_enqueue: dict = {}

    def submit(self, requests: List[Request],
               now: Optional[float] = None) -> None:
        """Validate and enqueue; raises before accepting any of them."""
        for req in requests:
            validate_request(req, self.max_len)
        now = time.perf_counter() if now is None else now
        for req in requests:
            self._queue.append(req)
            self._t_enqueue[id(req)] = now
            if self.metrics is not None \
                    and req.latency_target_s is not None:
                self.metrics.registry.histogram(
                    "serve_latency_target_s").observe(
                    req.latency_target_s)
        self._gauge()

    def t_enqueue(self, req: Request) -> float:
        return self._t_enqueue.get(id(req), 0.0)

    @property
    def pending(self) -> int:
        return len(self._queue)

    def _ordered(self) -> List[Request]:
        if self.policy == "fifo":
            return self._queue
        # EDF: deadline = enqueue + target; no target sorts last, FIFO
        # among equals (sort is stable, the queue is in FIFO order).
        return sorted(
            self._queue,
            key=lambda r: (r.latency_target_s is None,
                           self._t_enqueue[id(r)]
                           + (r.latency_target_s or 0.0)))

    def admit(self, free_slots: List[int],
              can_reserve: Callable[[int, Request], bool]
              ) -> List[tuple]:
        """Assign queued requests to free slots, in policy order.

        ``can_reserve(slot, req)`` is the KV manager's veto.  Each
        request takes the lowest-numbered free slot that can host it;
        the first request that fits nowhere blocks the queue (no
        overtaking), which keeps completion order deterministic.
        """
        placed = []
        free = sorted(free_slots)
        for req in self._ordered():
            slot = next((s for s in free if can_reserve(s, req)), None)
            if slot is None:
                break
            free.remove(slot)
            placed.append((slot, req))
        for _, req in placed:
            self._queue.remove(req)
        if self.policy == "edf" and self.slo is not None and placed:
            now = time.perf_counter()
            for _, req in placed:
                if req.latency_target_s is None:
                    continue
                overdue = now - (self._t_enqueue[id(req)]
                                 + req.latency_target_s)
                if overdue > 0:
                    self.slo.late_admission(overdue)
        self._gauge()
        return placed

    def forget(self, req: Request) -> None:
        self._t_enqueue.pop(id(req), None)

    def _gauge(self) -> None:
        if self.metrics is not None:
            self.metrics.registry.gauge("serve_queue_depth").set(
                len(self._queue))
