"""KV-cache managers: paged block-table allocation and the dense rectangle.

The dense layout gives every slot a ``max_len`` rectangle up front:
simple, but a 16-token request in a 512-token engine holds 32x the
cache bytes it ever touches.  The paged layout (vLLM-style) carves the
cache into fixed-size *blocks* shared by all slots through a per-slot
block table; blocks are allocated lazily as a slot's length crosses a
block boundary and returned on eviction, so resident cache bytes track
the *actual* tokens in flight, not the worst case.

Layout of the paged pool (see :meth:`repro.models.Model.init_paged_cache`
and :meth:`~repro.models.Model._paged_forward`)::

    pool:  (layers, num_blocks_total, kv_heads, block_size, head_dim)
    table: (slots, blocks_per_slot + 1) int32  — last column = trash

Allocation is **host-side and deterministic**: per-dp-group sorted free
lists, lowest id first, so two runs of the same trace produce identical
block tables (and the mesh test can compare token streams exactly).
Each dp group owns a contiguous range of pool rows whose first block is
the group's *trash block* — the write target for chunk padding and
masked decode writes — so every slot's blocks (and its trash) live on
its own dp shard and the block axis shards evenly.

Admission safety: :meth:`PagedKVCache.reserve` books the worst-case
block count (``ceil((prompt + max_new) / block_size)``) at admission
time, and :meth:`can_reserve` refuses admissions that could deadlock a
decoding request mid-stream — a request, once admitted, can always
grow to its reserved size.
"""

from __future__ import annotations

import bisect
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.models import Model

__all__ = ["PagedKVCache", "DenseKVCache"]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


class PagedKVCache:
    """Block-table KV manager over a shared pool of fixed-size blocks.

    Args:
      model: the LM (fixes layer/head/dim extents of the pool).
      batch_slots: number of engine slots (block-table rows).
      max_len: per-slot logical capacity; must be a multiple of
        ``block_size`` so the paged attention extent equals the dense
        one (that equality is what makes paged == dense bitwise).
      block_size: tokens per block.
      num_blocks: usable (data) blocks in the pool, shared by all
        slots; default ``batch_slots * max_len/block_size`` (the dense
        equivalent — no admission ever waits).  Rounded up to a
        multiple of ``dp_groups``; per-group trash blocks are added on
        top.
      dp_groups: data-parallel extent — slots and pool rows are split
        into this many contiguous groups so the device arrays shard
        evenly over the mesh dp axis.
      registry: optional :class:`repro.obs.Registry` for the block
        gauges (``serve_kv_blocks_allocated`` / ``_hwm`` /
        ``serve_kv_block_utilization``).
    """

    def __init__(self, model: Model, batch_slots: int, max_len: int,
                 block_size: int = 16,
                 num_blocks: Optional[int] = None, dp_groups: int = 1,
                 registry=None):
        if max_len % block_size:
            raise ValueError(
                f"max_len={max_len} must be a multiple of block_size="
                f"{block_size} (equal attention extents are what make "
                "the paged cache bit-identical to the dense one)")
        if batch_slots % dp_groups:
            raise ValueError(f"batch_slots={batch_slots} not divisible "
                             f"by dp_groups={dp_groups}")
        self.block_size = int(block_size)
        self.blocks_per_slot = max_len // block_size
        self.batch_slots = int(batch_slots)
        self.max_len = int(max_len)
        self.dp_groups = int(dp_groups)
        self._slots_per_group = batch_slots // dp_groups
        usable = int(num_blocks or batch_slots * self.blocks_per_slot)
        usable = _ceil_div(usable, dp_groups) * dp_groups
        self.num_blocks = usable                  # usable data blocks
        self._per_group = usable // dp_groups
        # Pool rows: each group owns [g*(per+1), (g+1)*(per+1)); the
        # first row of the range is the group's trash block.
        self.num_blocks_total = usable + dp_groups
        self._free: List[List[int]] = []
        self._trash: List[int] = []
        for g in range(dp_groups):
            base = g * (self._per_group + 1)
            self._trash.append(base)
            self._free.append(list(range(base + 1,
                                         base + 1 + self._per_group)))
        self._reserved = [0] * dp_groups          # booked, not yet mapped
        self._mapped: List[List[int]] = [[] for _ in range(batch_slots)]
        self._reserved_left = [0] * batch_slots
        self._registry = registry
        self.allocated_hwm = 0
        # Host mirror of the device block table; every entry starts at
        # the slot's trash block, so unmapped logical blocks read (and
        # padding writes hit) memory that is never attended unmasked.
        self._table = np.empty((batch_slots, self.blocks_per_slot + 1),
                               np.int32)
        for slot in range(batch_slots):
            self._table[slot, :] = self._trash[self.group_of(slot)]
        self._table_dirty = True
        self.pools = model.init_paged_cache(self.num_blocks_total,
                                            self.block_size)
        self._gauges()

    # -- geometry ----------------------------------------------------

    def group_of(self, slot: int) -> int:
        return slot // self._slots_per_group

    @property
    def allocated_blocks(self) -> int:
        return sum(len(m) for m in self._mapped)

    @property
    def dense_equivalent_blocks(self) -> int:
        """Blocks a dense rectangle layout would hold resident."""
        return self.batch_slots * self.blocks_per_slot

    def blocks_needed(self, prompt_len: int, max_new: int) -> int:
        return _ceil_div(prompt_len + max_new, self.block_size)

    # -- cache assembly ----------------------------------------------

    def init_cache(self) -> dict:
        """The full device cache dict the paged programs consume."""
        return {"k": self.pools["k"], "v": self.pools["v"],
                "block_table": jnp.asarray(self._table),
                "length": jnp.zeros((self.batch_slots,), jnp.int32)}

    def sync_table(self, cache: dict) -> dict:
        """Push the host block table to the device if it changed."""
        if self._table_dirty:
            cache = dict(cache, block_table=jnp.asarray(self._table))
            self._table_dirty = False
        return cache

    # -- allocation --------------------------------------------------

    def can_reserve(self, slot: int, prompt_len: int,
                    max_new: int) -> bool:
        """Would admitting this request into ``slot`` be deadlock-free?"""
        g = self.group_of(slot)
        need = self.blocks_needed(prompt_len, max_new)
        return need <= len(self._free[g]) - self._reserved[g]

    def reserve(self, slot: int, prompt_len: int, max_new: int) -> None:
        """Book the worst-case block count for a newly admitted request."""
        need = self.blocks_needed(prompt_len, max_new)
        if need > self._per_group:
            raise ValueError(
                f"request needs {need} blocks but the pool holds only "
                f"{self._per_group} per dp group — raise num_blocks or "
                "block_size")
        g = self.group_of(slot)
        if need > len(self._free[g]) - self._reserved[g]:
            raise RuntimeError(
                f"reserve() without can_reserve(): slot {slot} needs "
                f"{need} blocks, group {g} has "
                f"{len(self._free[g]) - self._reserved[g]} unbooked")
        self._reserved[g] += need
        self._reserved_left[slot] = need

    def ensure(self, slot: int, upto_len: int) -> None:
        """Map blocks so positions ``0 .. upto_len-1`` are backed."""
        g = self.group_of(slot)
        mapped = self._mapped[slot]
        while len(mapped) < _ceil_div(upto_len, self.block_size):
            block = self._free[g].pop(0)   # lowest id: deterministic
            self._table[slot, len(mapped)] = block
            mapped.append(block)
            if self._reserved_left[slot] > 0:
                self._reserved_left[slot] -= 1
                self._reserved[g] -= 1
            self._table_dirty = True
        self.allocated_hwm = max(self.allocated_hwm,
                                 self.allocated_blocks)
        self._gauges()

    def release(self, slot: int) -> None:
        """Return a finished slot's blocks and reservation to the pool."""
        g = self.group_of(slot)
        for block in self._mapped[slot]:
            bisect.insort(self._free[g], block)
        self._mapped[slot] = []
        self._reserved[g] -= self._reserved_left[slot]
        self._reserved_left[slot] = 0
        self._table[slot, :] = self._trash[g]
        self._table_dirty = True
        self._gauges()

    def _gauges(self) -> None:
        if self._registry is None:
            return
        alloc = self.allocated_blocks
        self._registry.gauge("serve_kv_blocks_allocated").set(alloc)
        self._registry.gauge("serve_kv_blocks_hwm").set(
            self.allocated_hwm)
        self._registry.gauge("serve_kv_block_utilization").set(
            alloc / max(self.num_blocks, 1))

    def stats(self) -> dict:
        return {"layout": "paged", "block_size": self.block_size,
                "num_blocks": self.num_blocks,
                "allocated_blocks": self.allocated_blocks,
                "allocated_hwm": self.allocated_hwm,
                "dense_equivalent_blocks": self.dense_equivalent_blocks}


class DenseKVCache:
    """The original rectangular layout behind the same manager API.

    Every slot owns a ``max_len`` rectangle for its lifetime; there is
    nothing to allocate or release, so reservation always succeeds and
    the "allocated" accounting equals the dense equivalent by
    definition.  Kept (and asserted bit-identical to paged) as the
    reference layout.
    """

    def __init__(self, model: Model, batch_slots: int, max_len: int,
                 registry=None):
        self.model = model
        self.batch_slots = int(batch_slots)
        self.max_len = int(max_len)
        self.allocated_hwm = batch_slots * max_len
        self._registry = registry

    def init_cache(self) -> dict:
        return self.model.init_cache(self.batch_slots, self.max_len)

    def sync_table(self, cache: dict) -> dict:
        return cache

    def can_reserve(self, slot: int, prompt_len: int,
                    max_new: int) -> bool:
        return True

    def reserve(self, slot: int, prompt_len: int, max_new: int) -> None:
        pass

    def ensure(self, slot: int, upto_len: int) -> None:
        pass

    def release(self, slot: int) -> None:
        pass

    def stats(self) -> dict:
        return {"layout": "dense",
                "dense_equivalent_tokens": self.batch_slots
                * self.max_len}
