"""Batched execution: chunked prefill waves interleaved with decode ticks.

The runner owns the device side of serving — the (possibly
offload-transformed) prefill-chunk and decode programs, the KV cache
pytree, and the host mirror of per-slot lengths.  It knows nothing
about queues or request lifecycles; the engine hands it admitted
requests and asks for one prefill wave or one decode tick at a time.

Chunked prefill
---------------
Prompts are ingested in *pieces* of at most ``chunk_tokens``, packed
FIFO into waves of at most ``chunk_token_budget`` total tokens — so a
4k-token prompt costs several short waves with decode ticks in
between instead of one monolithic stall.  A wave's width is the
largest piece in it (no power-of-two rounding: right-padding is pure
waste, and the packing satellite asserts we emit fewer padded tokens
than the pad-to-wave-max scheme).  Pieces whose slot rectangle cannot
absorb the wave width stop the wave early (head-of-line, order
preserved) — only relevant for the dense layout, whose chunk padding
is written in-rectangle; the paged layout routes padding to the trash
block.

Warm-start transform cache
--------------------------
With ``warm_cache_dir`` the offload wrapper persists its jaxpr
transform cache to disk (see :func:`repro.core.intercept.offload`), so
a restarted server skips re-tracing.  Because the persisted program is
serialized via ``jax.export`` — which cannot carry debug callbacks —
the per-execution site-event hook is replaced by *static accounting*:
after each program call the runner bumps ``site_exec`` by each
offloaded site's static trip multiplicity, which equals the hook's
count exactly for these forward-only programs.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import offload
from repro.models import Model
from repro.obs import get_logger

__all__ = ["Runner", "WaveResult"]

log = get_logger("serve")


class _NullSpan:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


#: Shared no-op context for the metrics-off path (contextlib.
#: nullcontext allocates per use; the engine ticks in a hot loop).
_NULL_SPAN = _NullSpan()


def _round_up(n: int, mult: int = 8) -> int:
    return ((n + mult - 1) // mult) * mult


class _Prefill:
    """One slot's in-flight prompt ingestion."""

    __slots__ = ("req", "tokens", "pos")

    def __init__(self, req):
        self.req = req
        self.tokens = np.asarray(req.prompt, np.int32)
        self.pos = 0

    @property
    def remaining(self) -> int:
        return len(self.tokens) - self.pos


@dataclasses.dataclass
class WaveResult:
    """What one prefill wave did (the engine's telemetry input)."""

    pieces: list          # (slot, req, take) in wave-row order
    completed: list       # (slot, req, sampled first token)
    rows: int             # device rows incl. dp padding
    width: int            # wave width (largest piece)
    padded_tokens: int    # rows * width actually computed
    real_tokens: int      # sum of piece lengths
    duration_s: float


class Runner:
    """Executes prefill waves and decode ticks over one KV cache."""

    def __init__(self, model: Model, params, kv, *, max_len: int,
                 mesh=None, dp_size: int = 1, slot_sharding=None,
                 kv_sharding=None, policy=None, plan=None,
                 metrics=None, chunk_tokens: Optional[int] = None,
                 chunk_token_budget: Optional[int] = None,
                 warm_cache_dir=None):
        self.model = model
        self.params = params
        self.kv = kv
        self.max_len = int(max_len)
        self.mesh = mesh
        self._dp_size = int(dp_size)
        self._slot_sharding = slot_sharding
        self._kv_sharding = kv_sharding
        self.policy = policy
        self.plan = plan
        self.metrics = metrics
        self.layout = kv.stats()["layout"]
        self.chunk_tokens = (int(chunk_tokens) if chunk_tokens
                             else self.max_len)
        self.chunk_token_budget = (int(chunk_token_budget)
                                   if chunk_token_budget else None)
        self.batch_slots = kv.batch_slots
        self._persist_dir = None
        if warm_cache_dir is not None:
            if policy is None:
                log.debug("warm_cache_dir ignored: no policy/plan, so "
                          "there is no transform cache to persist")
            elif mesh is not None:
                log.debug("warm_cache_dir ignored under a mesh: "
                          "exported programs would bake in this "
                          "process's device topology")
            else:
                self._persist_dir = warm_cache_dir
        # Static site accounting replaces the per-execution debug-
        # callback hook whenever the transform cache persists (exported
        # programs cannot carry callbacks).
        self._static_sites = (self._persist_dir is not None
                              and metrics is not None)
        self._declared = False
        self._seen_static: set = set()

        if self.layout == "paged":
            prefill_fn = model.prefill_chunk_paged
            decode_fn = model.decode_step_paged
        else:
            prefill_fn = model.prefill_chunk
            decode_fn = model.decode_step
        self._prefill_wrapped, self._prefill_call = self._wrap(
            prefill_fn, f"serve_prefill_{self.layout}")
        self._decode_wrapped, self._decode_call = self._wrap(
            decode_fn, f"serve_decode_{self.layout}")

        self.cache = self._pin(kv.init_cache())
        self._len = np.zeros(self.batch_slots, np.int64)
        self._pending: dict = {}      # slot -> _Prefill (admission order)
        # Lifetime totals (prefill cost accounting: computed prefill
        # FLOPs scale with padded tokens, useful ones with real).
        self.waves_total = 0
        self.padded_tokens_total = 0
        self.real_tokens_total = 0

    # -- program wiring ----------------------------------------------

    def _wrap(self, fn, label):
        """(inspectable wrapper, callable) for one serve program."""
        if self.policy is None:
            return None, jax.jit(fn)
        if self._persist_dir is not None:
            wrapped = offload(
                fn, self.policy, plan=self.plan, plan_match="subset",
                persist_dir=self._persist_dir, fn_label=label,
                jit_entries=True, on_cache_event=self._cache_event)
            # jit_entries compiles per cache entry (or runs the
            # deserialized exported program); no outer jit.
            return wrapped, wrapped
        hook = (self.metrics.site_event_handler()
                if self.metrics is not None else None)
        wrapped = offload(fn, self.policy, plan=self.plan,
                          plan_match="subset", on_site_event=hook)
        return wrapped, jax.jit(wrapped)

    def _cache_event(self, kind: str) -> None:
        if self.metrics is None:
            return
        self.metrics.registry.counter("transform_cache",
                                      result=kind).inc()
        self.metrics.event("transform_cache", result=kind)

    def _pin(self, cache: dict) -> dict:
        """Re-assert slot/kv shardings on the cache pytree (no-op
        off-mesh, no-copy when the layout already matches)."""
        if self.mesh is None:
            return cache
        out = {"k": jax.device_put(cache["k"], self._kv_sharding),
               "v": jax.device_put(cache["v"], self._kv_sharding),
               "length": jax.device_put(cache["length"],
                                        self._slot_sharding)}
        if "block_table" in cache:
            out["block_table"] = jax.device_put(cache["block_table"],
                                                self._slot_sharding)
        return out

    def _shard(self, *arrays):
        if self.mesh is None:
            return arrays
        return tuple(jax.device_put(a, self._slot_sharding)
                     for a in arrays)

    def _span(self, name, **kw):
        if self.metrics is None:
            return _NULL_SPAN
        return self.metrics.tracer.span(name, **kw)

    # -- site telemetry ----------------------------------------------

    def _declare_once(self, args) -> None:
        if (self.metrics is None or self._prefill_wrapped is None
                or self._declared):
            return
        # First wave: record the site decisions (same records
        # ``site_report`` would produce) so ``repro.obs report --check``
        # can hold execution counts against them.  Warms the exact
        # transform-cache entry the call below hits.
        self.metrics.declare_sites(self._prefill_wrapped.sites(*args))
        self._declared = True

    def _account(self, wrapped, args) -> None:
        """Static ``site_exec`` accounting for the warm-cache path."""
        if not self._static_sites or wrapped is None:
            return
        for s in wrapped.sites(*args):
            if not s.offloaded:
                continue
            self.metrics.registry.counter(
                "site_exec", site=s.name).inc(s.mult)
            if s.name not in self._seen_static:
                self._seen_static.add(s.name)
                self.metrics.event(
                    "site_exec", site=s.name, backend=s.backend,
                    splits=int(s.splits), counted="static")

    def sites_for(self, rows: int, width: int):
        """Site decisions of the prefill-chunk program for a wave shape
        (introspection; does not execute anything)."""
        if self._prefill_wrapped is None:
            return []
        return self._prefill_wrapped.sites(
            *self._abstract_wave_args(rows, width))

    def _abstract_wave_args(self, rows: int, width: int):
        sds = jax.ShapeDtypeStruct
        i32 = jnp.int32
        spec = jax.tree_util.tree_map(
            lambda a: sds(jnp.shape(a), jnp.result_type(a)),
            self.params)
        tokens = sds((rows, width), i32)
        vec = sds((rows,), i32)
        if self.layout == "paged":
            k = sds(self.cache["k"].shape, self.cache["k"].dtype)
            table = sds((rows, self.kv.blocks_per_slot + 1), i32)
            return (spec, k, k, table, tokens, vec, vec)
        cfg = self.model.cfg
        sub = sds((cfg.num_layers, rows, cfg.num_kv_heads,
                   self.max_len, cfg.head_dim), self.model.dtype)
        return (spec, sub, sub, tokens, vec, vec)

    # -- sampling ----------------------------------------------------

    def _sample(self, logits_dev, reqs: List) -> np.ndarray:
        """Greedy on device; temperature>0 rows re-sampled host-side
        from a per-request deterministic stream (seeded by the request
        seed and the emission index, so batching never changes a
        sampled request's tokens)."""
        toks = np.array(self.model.greedy(logits_dev))  # writable copy
        hot = [i for i, r in enumerate(reqs)
               if r is not None and r.temperature > 0]
        if hot:
            lg = np.asarray(logits_dev).astype(np.float64)
            for i in hot:
                r = reqs[i]
                z = lg[i] / r.temperature
                z -= z.max()
                p = np.exp(z)
                p /= p.sum()
                rng = np.random.default_rng(
                    [r.seed & 0xFFFFFFFF, len(r.out)])
                toks[i] = rng.choice(p.size, p=p)
        return toks

    # -- prefill -----------------------------------------------------

    def enqueue_prefill(self, slot: int, req) -> None:
        self._pending[slot] = _Prefill(req)

    def is_prefilling(self, slot: int) -> bool:
        return slot in self._pending

    @property
    def prefilling(self) -> bool:
        return bool(self._pending)

    def _pack(self) -> List[tuple]:
        """Pick this wave's pieces: FIFO, chunk-capped, budget-capped.

        The wave width is the largest accepted piece; a piece is only
        accepted if every already-accepted piece's rectangle can absorb
        that width (``pos + width <= max_len``) — a solo piece always
        fits (``pos + take <= prompt_len <= max_len``), so the wave is
        never empty and head-of-line order holds.
        """
        budget = self.chunk_token_budget or float("inf")
        pieces, width = [], 0
        for slot, st in self._pending.items():
            if budget <= 0:
                break
            take = int(min(self.chunk_tokens, st.remaining, budget))
            if take <= 0:
                break
            new_width = max(width, take)
            ok = all(p.pos + new_width <= self.max_len
                     for _, p, _ in pieces + [(slot, st, take)])
            if not ok:
                break
            pieces.append((slot, st, take))
            width = new_width
            budget -= take
        return pieces

    def prefill_wave(self) -> Optional[WaveResult]:
        """Run one packed prefill wave; returns None when idle."""
        if not self._pending:
            return None
        pieces = self._pack()
        t0 = time.perf_counter()
        width = max(take for _, _, take in pieces)
        n = len(pieces)
        rows = (n if self.mesh is None
                else _round_up(n, self._dp_size))
        tokens = np.zeros((rows, width), np.int32)
        start = np.zeros((rows,), np.int32)
        piece = np.ones((rows,), np.int32)
        for i, (slot, st, take) in enumerate(pieces):
            tokens[i, :take] = st.tokens[st.pos:st.pos + take]
            start[i] = st.pos
            piece[i] = take
        if self.layout == "paged":
            # Dummy rows: no writes at all (their reads hit trash).
            piece[n:] = 0
        span = self._span("prefill", rows=rows, padded_len=width,
                          chunks=n)
        with span:
            if self.layout == "paged":
                logits = self._wave_paged(pieces, tokens, start, piece,
                                          rows, n)
            else:
                logits = self._wave_dense(pieces, tokens, start, piece,
                                          rows, n)
            # Scatter the new per-slot lengths (host-known): decoding
            # neighbours keep theirs, wave slots move to their chunk
            # end — which also parks the dense layout's masked decode
            # writes at a position the next chunk overwrites first.
            ends = np.array([st.pos + take for _, st, take in pieces],
                            np.int32)
            jslots = jnp.asarray(
                np.array([s for s, _, _ in pieces]))
            self.cache = self._pin(dict(
                self.cache,
                length=self.cache["length"].at[jslots].set(
                    jnp.asarray(ends))))
            completed = []
            done_rows = []
            reqs_rows = [None] * n
            for i, (slot, st, take) in enumerate(pieces):
                self._len[slot] = st.pos + take
                st.pos += take
                if st.remaining == 0:
                    del self._pending[slot]
                    done_rows.append(i)
                    reqs_rows[i] = st.req
            # np.asarray inside _sample blocks on the device work, so
            # the span (and prefill_s) covers the wave, not dispatch.
            toks = self._sample(logits[:n], reqs_rows)
            for i in done_rows:
                slot, st, _ = pieces[i]
                completed.append((slot, st.req, int(toks[i])))
        self.waves_total += 1
        self.padded_tokens_total += rows * width
        self.real_tokens_total += int(sum(t for _, _, t in pieces))
        return WaveResult(
            pieces=[(s, st.req, t) for s, st, t in pieces],
            completed=completed, rows=rows, width=width,
            padded_tokens=rows * width,
            real_tokens=int(sum(t for _, _, t in pieces)),
            duration_s=time.perf_counter() - t0)

    def _wave_paged(self, pieces, tokens, start, piece, rows, n):
        for slot, st, take in pieces:
            self.kv.ensure(slot, st.pos + take)
        self.cache = self.kv.sync_table(self.cache)
        table = np.empty((rows, self.kv.blocks_per_slot + 1), np.int32)
        for i, (slot, _, _) in enumerate(pieces):
            table[i] = self.kv._table[slot]
        for i in range(n, rows):
            g = 0 if self.mesh is None else i // (rows // self._dp_size)
            table[i] = self.kv._trash[g]
        tok_d, start_d, piece_d, table_d = self._shard(
            jnp.asarray(tokens), jnp.asarray(start),
            jnp.asarray(piece), jnp.asarray(table))
        args = (self.params, self.cache["k"], self.cache["v"],
                table_d, tok_d, start_d, piece_d)
        self._declare_once(args)
        k_new, v_new, logits = self._prefill_call(*args)
        self._account(self._prefill_wrapped, args)
        self.cache = self._pin(dict(self.cache, k=k_new, v=v_new))
        return logits

    def _wave_dense(self, pieces, tokens, start, piece, rows, n):
        slots = np.array([s for s, _, _ in pieces])
        jidx = jnp.asarray(np.concatenate(
            [slots, np.zeros(rows - n, np.int64)]))
        sub_k = self.cache["k"][:, jidx]
        sub_v = self.cache["v"][:, jidx]
        tok_d, start_d, piece_d = self._shard(
            jnp.asarray(tokens), jnp.asarray(start),
            jnp.asarray(piece))
        args = (self.params, sub_k, sub_v, tok_d, start_d, piece_d)
        self._declare_once(args)
        k_new, v_new, logits = self._prefill_call(*args)
        self._account(self._prefill_wrapped, args)
        jreal = jnp.asarray(slots)
        self.cache = self._pin(dict(
            self.cache,
            k=self.cache["k"].at[:, jreal].set(k_new[:, :n]),
            v=self.cache["v"].at[:, jreal].set(v_new[:, :n])))
        return logits

    # -- decode ------------------------------------------------------

    def decode_tick(self, next_token: np.ndarray, active: np.ndarray,
                    reqs: List) -> np.ndarray:
        """One masked decode step across all slots; returns sampled
        tokens for the active ones (others carry garbage)."""
        if self.layout == "paged":
            for slot in np.flatnonzero(active):
                self.kv.ensure(int(slot), int(self._len[slot]) + 1)
            self.cache = self.kv.sync_table(self.cache)
        tokens, act = self._shard(jnp.asarray(next_token),
                                  jnp.asarray(active))
        span = self._span("decode_tick", active=int(active.sum()))
        with span:
            args = (self.params, self.cache, tokens, act)
            cache, logits = self._decode_call(*args)
            self._account(self._decode_wrapped, args)
            self.cache = self._pin(cache)
            # Blocks, so the span covers the device step.
            toks = self._sample(logits, reqs)
        self._len[active] += 1
        return toks
