"""Continuous-batching inference engine over the KV-cache programs.

The engine owns a fixed number of *slots* (the batch axis of one shared
KV cache).  Requests queue for a free slot; newly admitted requests are
prefilled together as one right-padded sub-batch and scattered into the
shared cache; every engine tick then runs a single batched greedy
``decode_step`` across all slots (idle slots are masked); finished
requests are evicted and their slots immediately readmit queued work —
so the decode batch stays as full as the workload allows, which is the
whole point of continuous batching.

Numerics note: each slot's computation is independent of its batch
neighbours (attention is masked per slot, matmuls are batched but not
mixed), so a prompt decoded in a busy batch yields the same greedy
tokens as the same prompt decoded alone — the serve tests assert this.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model

__all__ = ["Engine", "Request"]


@dataclasses.dataclass
class Request:
    """One generation request; ``out`` fills as the engine decodes."""

    prompt: List[int]
    max_new_tokens: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _round_up(n: int, mult: int = 8) -> int:
    return ((n + mult - 1) // mult) * mult


class Engine:
    """Greedy continuous-batching engine.

    Args:
      model: the :class:`~repro.models.Model` (its config fixes the
        vocabulary and ``eos_id``).
      params: parameter pytree (trained or fresh).
      batch_slots: decode batch width = number of concurrent requests.
      max_len: KV-cache capacity per slot; a request finishes early if
        ``prompt + generated`` would outgrow it.
    """

    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_len: int = 512):
        self.model = model
        self.params = params
        self.batch_slots = int(batch_slots)
        self.max_len = int(max_len)
        self.cache = model.init_cache(self.batch_slots, self.max_len)
        self.slots: List[Optional[Request]] = [None] * self.batch_slots
        self._next_token = np.zeros(self.batch_slots, np.int32)
        # One compile per (admitted sub-batch size, padded prompt
        # length) pair; decode compiles once.  Fine at example scale —
        # pad admission waves to batch_slots if this ever dominates.
        self._prefill = jax.jit(
            lambda p, t, n: model.prefill(p, t, n, self.max_len))
        self._decode = jax.jit(model.decode_step)

    # -- lifecycle ---------------------------------------------------

    def _admit(self, queue: "deque[Request]") -> None:
        free = [i for i, r in enumerate(self.slots) if r is None]
        batch = []
        while free and queue:
            req = queue.popleft()
            if not req.prompt:
                raise ValueError("empty prompt")
            if req.max_new_tokens < 1:
                raise ValueError("max_new_tokens must be >= 1 "
                                 "(the engine always decodes the "
                                 "prompt's continuation)")
            if len(req.prompt) + req.max_new_tokens > self.max_len:
                raise ValueError(
                    f"prompt({len(req.prompt)}) + max_new_tokens"
                    f"({req.max_new_tokens}) exceeds max_len="
                    f"{self.max_len}")
            batch.append((free.pop(0), req))
        if not batch:
            return
        idx = np.array([i for i, _ in batch])
        lengths = np.array([len(r.prompt) for _, r in batch], np.int32)
        P = min(_round_up(int(lengths.max())), self.max_len)
        tokens = np.zeros((len(batch), P), np.int32)
        for row, (_, req) in enumerate(batch):
            tokens[row, :len(req.prompt)] = req.prompt
        sub_cache, last_logits = self._prefill(
            self.params, jnp.asarray(tokens), jnp.asarray(lengths))
        # Scatter the sub-batch cache into the shared slots.
        jidx = jnp.asarray(idx)
        self.cache = {
            "k": self.cache["k"].at[:, jidx].set(sub_cache["k"]),
            "v": self.cache["v"].at[:, jidx].set(sub_cache["v"]),
            "length": self.cache["length"].at[jidx].set(
                sub_cache["length"]),
        }
        first = np.asarray(self.model.greedy(last_logits))
        for row, (slot, req) in enumerate(batch):
            self.slots[slot] = req
            self._emit(slot, req, int(first[row]))

    def _emit(self, slot: int, req: Request, token: int) -> None:
        req.out.append(token)
        self._next_token[slot] = token
        eos = self.model.cfg.eos_id
        length_next = len(req.prompt) + len(req.out)
        if (len(req.out) >= req.max_new_tokens
                or (eos is not None and token == eos)
                or length_next >= self.max_len):
            req.done = True
            self.slots[slot] = None

    def _tick(self) -> None:
        active = np.array([r is not None for r in self.slots])
        if not active.any():
            return
        self.cache, logits = self._decode(
            self.params, self.cache,
            jnp.asarray(self._next_token), jnp.asarray(active))
        nxt = np.asarray(self.model.greedy(logits))
        for slot, req in enumerate(list(self.slots)):
            if req is not None:
                self._emit(slot, req, int(nxt[slot]))

    # -- public API --------------------------------------------------

    def run(self, requests: List[Request]) -> List[Request]:
        """Drive all ``requests`` to completion; returns them in order.

        Admission is FIFO; more requests than slots simply queue and
        are admitted as earlier ones finish.
        """
        queue = deque(requests)
        while queue or any(r is not None for r in self.slots):
            self._admit(queue)
            self._tick()
        return requests
