"""Continuous-batching inference engine over the KV-cache programs.

The engine owns a fixed number of *slots* (the batch axis of one shared
KV cache).  Requests queue for a free slot; newly admitted requests are
prefilled together as one right-padded sub-batch and scattered into the
shared cache; every engine tick then runs a single batched greedy
``decode_step`` across all slots (idle slots are masked); finished
requests are evicted and their slots immediately readmit queued work —
so the decode batch stays as full as the workload allows, which is the
whole point of continuous batching.

Numerics note: each slot's computation is independent of its batch
neighbours (attention is masked per slot, matmuls are batched but not
mixed), so a prompt decoded in a busy batch yields the same greedy
tokens as the same prompt decoded alone — the serve tests assert this.

Tunable-precision serving: pass ``plan=`` (a
:class:`repro.tune.PrecisionPlan`) or ``policy=`` to run the prefill
and decode GEMMs through the automatic offload transform — the same
plan artifact the training loop consumes, applied in subset mode
because serving traces only the forward sites.

Multi-device serving: pass ``mesh=`` to shard the engine across the
slot (batch) axis — parameters replicated, the KV cache and every
prefill/decode batch partitioned over the data-parallel axis, so each
dp group owns ``batch_slots / dp`` slots.  Prefill waves are
right-padded to a multiple of the dp extent so the sub-batch always
divides evenly.  Per-slot independence (above) makes the sharded
engine emit exactly the tokens the single-device engine would.

A 2-D ``dp×tp`` mesh additionally shards the *parameters* for
prefill/decode per the LM axis rules (:mod:`repro.shard.rules`):
attention heads and the SwiGLU hidden dim split over ``tp``, the KV
cache split over ``tp`` on its kv-head axis — XLA's SPMD partitioner
inserts the tp collectives from the sharding annotations, so each
device holds ``1/tp`` of every projection and ``1/(dp*tp)`` of the
KV cache.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.core import PrecisionPolicy, offload
from repro.models import Model
from repro.obs import get_logger
from repro.shard import (TP_AXIS, data_parallel_sharding,
                         lm_param_specs, state_shardings, validate_tp)

__all__ = ["Engine", "Request"]

log = get_logger("serve")


@dataclasses.dataclass
class Request:
    """One generation request; ``out`` fills as the engine decodes."""

    prompt: List[int]
    max_new_tokens: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _round_up(n: int, mult: int = 8) -> int:
    return ((n + mult - 1) // mult) * mult


class _NullSpan:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


#: Shared no-op context for the metrics-off path (contextlib.
#: nullcontext allocates per use; the engine ticks in a hot loop).
_NULL_SPAN = _NullSpan()


class Engine:
    """Greedy continuous-batching engine.

    Args:
      model: the :class:`~repro.models.Model` (its config fixes the
        vocabulary and ``eos_id``).
      params: parameter pytree (trained or fresh).
      batch_slots: decode batch width = number of concurrent requests.
      max_len: KV-cache capacity per slot; a request finishes early if
        ``prompt + generated`` would outgrow it.
      mesh: optional :class:`jax.sharding.Mesh`; shards the slot axis
        over the data-parallel axis (``batch_slots`` must divide by
        the dp extent).  A 2-D ``dp×tp`` mesh also tp-shards the
        parameters and the KV cache's kv-head axis per the LM axis
        rules (``tp`` must divide ``num_kv_heads``).
      plan: optional :class:`repro.tune.PrecisionPlan` loaded at
        startup — the prefill and decode programs run through the
        automatic offload transform under the plan's policy.  Plans
        are usually calibrated on the *training* step, which covers a
        superset of the serve sites (the backward sites never appear
        here), so the plan is applied in subset mode: matching
        canonical sites get their tuned split counts, everything else
        keeps the plan's defaults, and no staleness error is raised
        for the training-only entries.
      policy: optional :class:`~repro.core.PrecisionPolicy` — same
        effect, explicit policy instead of a plan artifact (wins over
        ``plan`` for the transform configuration if both are given).
      metrics: optional :class:`repro.obs.MetricsRun` — per-request
        latency telemetry (admission wait, prefill time, time to first
        token, decode throughput), slot-occupancy gauges, prefill/
        decode tracer spans, and (under a plan/policy) per-site GEMM
        execution counts, all streamed into the run's JSONL file.
    """

    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_len: int = 512, mesh=None, plan=None,
                 policy: Optional[PrecisionPolicy] = None,
                 metrics=None):
        self.model = model
        self.metrics = metrics
        self.batch_slots = int(batch_slots)
        self.max_len = int(max_len)
        self.mesh = mesh
        self._dp_size = 1
        if mesh is not None:
            shape = dict(mesh.shape)
            tp = shape.get(TP_AXIS, 1)
            dp_axis = next((a for a in mesh.axis_names
                            if a != TP_AXIS), mesh.axis_names[0])
            self._dp_size = shape[dp_axis]
            if self.batch_slots % self._dp_size:
                raise ValueError(
                    f"batch_slots={self.batch_slots} is not divisible "
                    f"by the data-parallel extent {dp_axis}="
                    f"{self._dp_size}")
            # The canonical placements come from repro.shard; only
            # the KV layout (slots on dim 1 of (layers, batch, ...))
            # is serve-specific.
            if tp > 1:
                # 2-D: parameters tp-sharded per the LM axis rules,
                # KV cache additionally split over tp on its kv-head
                # axis (dim 2); XLA's SPMD partitioner derives the tp
                # collectives from these annotations.
                validate_tp(model.cfg, tp)
                params = jax.device_put(
                    params,
                    state_shardings(mesh, lm_param_specs(model.cfg)))
                self._slot_sharding = NamedSharding(
                    mesh, PartitionSpec(dp_axis))
                self._kv_sharding = NamedSharding(
                    mesh, PartitionSpec(None, dp_axis, TP_AXIS))
            else:
                replicated, self._slot_sharding = \
                    data_parallel_sharding(mesh, dp_axis)
                self._kv_sharding = NamedSharding(
                    mesh, PartitionSpec(None, dp_axis))
                params = jax.device_put(params, replicated)
        self.params = params
        self.cache = self._pin(
            model.init_cache(self.batch_slots, self.max_len))
        self.slots: List[Optional[Request]] = [None] * self.batch_slots
        self._next_token = np.zeros(self.batch_slots, np.int32)
        if policy is None and plan is not None:
            # Unmatched-site handling must be silent: a train-
            # calibrated plan legitimately carries backward-pass
            # entries that no serve program contains.
            policy = PrecisionPolicy.from_plan(
                plan, on_unmatched_site="ignore")
        self.plan = plan
        self.policy = policy

        # Per-request latency bookkeeping, keyed by request identity
        # (Request is a plain mutable dataclass, not hashable by value).
        self._rstats: dict = {}
        self._sites_declared = False

        def _maybe_offload(fn):
            if policy is None:
                return fn
            hook = (metrics.site_event_handler()
                    if metrics is not None else None)
            return offload(fn, policy, plan=plan, plan_match="subset",
                           on_site_event=hook)

        # One compile per (admitted sub-batch size, padded prompt
        # length) pair; decode compiles once.  Fine at example scale —
        # pad admission waves to batch_slots if this ever dominates.
        # The pre-jit wrappers stay inspectable (``.sites(...)`` when
        # a policy/plan is active).
        self._prefill_fn = _maybe_offload(
            lambda p, t, n: model.prefill(p, t, n, self.max_len))
        self._decode_fn = _maybe_offload(model.decode_step)
        self._prefill = jax.jit(self._prefill_fn)
        self._decode = jax.jit(self._decode_fn)

    def _pin(self, cache: dict) -> dict:
        """Re-assert the slot-axis sharding on a cache pytree.

        No-op without a mesh (and a no-copy no-op when the layout
        already matches); after a host-side scatter or a decode step
        this keeps the cache partitioned slot-wise instead of drifting
        to whatever layout the last op produced.
        """
        if self.mesh is None:
            return cache
        return {"k": jax.device_put(cache["k"], self._kv_sharding),
                "v": jax.device_put(cache["v"], self._kv_sharding),
                "length": jax.device_put(cache["length"],
                                         self._slot_sharding)}

    # -- lifecycle ---------------------------------------------------

    def _admit(self, queue: "deque[Request]") -> None:
        free = [i for i, r in enumerate(self.slots) if r is None]
        batch = []
        while free and queue:
            req = queue.popleft()
            if not req.prompt:
                raise ValueError("empty prompt")
            if req.max_new_tokens < 1:
                raise ValueError("max_new_tokens must be >= 1 "
                                 "(the engine always decodes the "
                                 "prompt's continuation)")
            if len(req.prompt) + req.max_new_tokens > self.max_len:
                raise ValueError(
                    f"prompt({len(req.prompt)}) + max_new_tokens"
                    f"({req.max_new_tokens}) exceeds max_len="
                    f"{self.max_len}")
            batch.append((free.pop(0), req))
        if not batch:
            return
        idx = np.array([i for i, _ in batch])
        lengths = np.array([len(r.prompt) for _, r in batch], np.int32)
        P = min(_round_up(int(lengths.max())), self.max_len)
        # With a mesh the wave is right-padded (dummy rows: empty
        # prompt, length 1) to a multiple of the dp extent so the
        # prefill batch shards evenly; dummy rows are dropped before
        # the scatter.
        rows = (len(batch) if self.mesh is None
                else _round_up(len(batch), self._dp_size))
        tokens = np.zeros((rows, P), np.int32)
        for row, (_, req) in enumerate(batch):
            tokens[row, :len(req.prompt)] = req.prompt
        lengths = np.concatenate(
            [lengths, np.ones(rows - len(batch), np.int32)])
        tokens, lengths = jnp.asarray(tokens), jnp.asarray(lengths)
        if self.mesh is not None:
            tokens = jax.device_put(tokens, self._slot_sharding)
            lengths = jax.device_put(lengths, self._slot_sharding)
        if (self.metrics is not None and self.policy is not None
                and not self._sites_declared):
            # First prefill: record the site decisions (same records
            # ``site_report`` would produce) so ``repro.obs report
            # --check`` can hold execution counts against them.  Warms
            # the same transform-cache entry the call below hits.
            self.metrics.declare_sites(
                self._prefill_fn.sites(self.params, tokens, lengths))
            self._sites_declared = True
        t_admit = time.perf_counter()
        span = (self.metrics.tracer.span("prefill", rows=rows,
                                         padded_len=P)
                if self.metrics is not None else _NULL_SPAN)
        with span:
            sub_cache, last_logits = self._prefill(self.params, tokens,
                                                   lengths)
            # Scatter the real sub-batch rows into the shared slots.
            jidx = jnp.asarray(idx)
            n = len(batch)
            self.cache = self._pin({
                "k": self.cache["k"].at[:, jidx].set(
                    sub_cache["k"][:, :n]),
                "v": self.cache["v"].at[:, jidx].set(
                    sub_cache["v"][:, :n]),
                "length": self.cache["length"].at[jidx].set(
                    sub_cache["length"][:n]),
            })
            # np.asarray blocks on the device work, so the span (and
            # prefill_s) covers the whole prefill, not the dispatch.
            first = np.asarray(self.model.greedy(last_logits))
        prefill_s = time.perf_counter() - t_admit
        if self.metrics is not None:
            log.debug(f"admitted wave of {len(batch)} "
                      f"(padded {rows}x{P}) in {prefill_s * 1e3:.1f} ms")
        for row, (slot, req) in enumerate(batch):
            st = self._rstats.get(id(req))
            if st is not None:
                st["admission_wait_s"] = t_admit - st["t_enqueue"]
                st["prefill_s"] = prefill_s
                st["t_admit"] = t_admit
                self.metrics.registry.histogram(
                    "serve_admission_wait_s").observe(
                    st["admission_wait_s"])
                self.metrics.registry.histogram(
                    "serve_prefill_s").observe(prefill_s)
            self.slots[slot] = req
            self._emit(slot, req, int(first[row]))

    def _emit(self, slot: int, req: Request, token: int) -> None:
        req.out.append(token)
        st = self._rstats.get(id(req))
        if st is not None and "ttft_s" not in st:
            # First emitted token (from the prefill's last logits).
            st["ttft_s"] = time.perf_counter() - st["t_enqueue"]
            self.metrics.registry.histogram(
                "serve_ttft_s").observe(st["ttft_s"])
        self._next_token[slot] = token
        eos = self.model.cfg.eos_id
        length_next = len(req.prompt) + len(req.out)
        if (len(req.out) >= req.max_new_tokens
                or (eos is not None and token == eos)
                or length_next >= self.max_len):
            req.done = True
            self.slots[slot] = None
            if st is not None:
                self._finish(req, st)

    def _finish(self, req: Request, st: dict) -> None:
        """Finalize one request's telemetry: the ``request`` event."""
        gen_s = time.perf_counter() - st.get("t_admit",
                                             st["t_enqueue"])
        tokens_per_s = len(req.out) / max(gen_s, 1e-9)
        self.metrics.registry.counter("serve_tokens").inc(len(req.out))
        self.metrics.event(
            "request", prompt_len=len(req.prompt),
            new_tokens=len(req.out),
            admission_wait_s=st.get("admission_wait_s"),
            prefill_s=st.get("prefill_s"), ttft_s=st.get("ttft_s"),
            decode_ticks=st.get("decode_ticks", 0),
            tokens_per_s=tokens_per_s)
        log.debug(f"request done: {len(req.prompt)} prompt + "
                  f"{len(req.out)} new tokens, "
                  f"ttft {st.get('ttft_s', 0) * 1e3:.1f} ms, "
                  f"{tokens_per_s:.1f} tok/s")
        self._rstats.pop(id(req), None)

    def _tick(self) -> None:
        active = np.array([r is not None for r in self.slots])
        if not active.any():
            return
        if self.metrics is not None:
            self.metrics.registry.gauge("serve_slot_occupancy").set(
                int(active.sum()))
            for req in self.slots:
                st = (self._rstats.get(id(req))
                      if req is not None else None)
                if st is not None:
                    st["decode_ticks"] = st.get("decode_ticks", 0) + 1
        tokens = jnp.asarray(self._next_token)
        active_dev = jnp.asarray(active)
        if self.mesh is not None:
            tokens = jax.device_put(tokens, self._slot_sharding)
            active_dev = jax.device_put(active_dev,
                                        self._slot_sharding)
        span = (self.metrics.tracer.span("decode_tick",
                                         active=int(active.sum()))
                if self.metrics is not None else _NULL_SPAN)
        with span:
            cache, logits = self._decode(self.params, self.cache,
                                         tokens, active_dev)
            # Re-pin (no-copy when the layout already matches) so the
            # KV cache stays slot-partitioned even if output-sharding
            # propagation ever produces a different layout.
            self.cache = self._pin(cache)
            # Blocks, so the span covers the device step.
            nxt = np.asarray(self.model.greedy(logits))
        for slot, req in enumerate(list(self.slots)):
            if req is not None:
                self._emit(slot, req, int(nxt[slot]))

    # -- public API --------------------------------------------------

    def run(self, requests: List[Request]) -> List[Request]:
        """Drive all ``requests`` to completion; returns them in order.

        Admission is FIFO; more requests than slots simply queue and
        are admitted as earlier ones finish.
        """
        queue = deque(requests)
        if self.metrics is not None:
            t0 = time.perf_counter()
            for req in requests:
                self._rstats[id(req)] = {"t_enqueue": t0}
        while queue or any(r is not None for r in self.slots):
            self._admit(queue)
            self._tick()
        if self.metrics is not None:
            self.metrics.registry.gauge("serve_slot_occupancy").set(0)
            # Site-event callbacks (plan/policy runs) are async; drain
            # them so execution counters are complete at flush time.
            jax.effects_barrier()
        return requests
