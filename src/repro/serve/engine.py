"""Continuous-batching inference engine (facade over the serve layers).

The engine wires four single-purpose layers together and drives the
serve loop; each layer is independently testable and none reaches into
another's state:

- :class:`repro.serve.scheduler.Scheduler` — request validation,
  queueing, slot assignment (FIFO default, optional EDF).
- :class:`repro.serve.kvcache.PagedKVCache` /
  :class:`~repro.serve.kvcache.DenseKVCache` — cache layout and block
  allocation, behind one manager API.
- :class:`repro.serve.runner.Runner` — the device programs: packed
  chunked-prefill waves interleaved with masked decode ticks.
- this facade — slot lifecycle, per-request telemetry, the public
  ``run()`` API (unchanged from the monolithic engine it replaced, and
  token-identical to it for greedy requests).

Numerics note: each slot's computation is independent of its batch
neighbours (attention is masked per slot, matmuls are batched but not
mixed), so a prompt decoded in a busy batch yields the same greedy
tokens as the same prompt decoded alone — the serve tests assert this.
The paged layout is additionally bit-identical to the dense rectangle
(its attention gathers reproduce the dense buffer layout exactly), so
the default ``kv_layout="paged"`` changes allocation, not tokens.

Tunable-precision serving: pass ``plan=`` (a
:class:`repro.tune.PrecisionPlan`) or ``policy=`` to run the prefill
and decode GEMMs through the automatic offload transform — the same
plan artifact the training loop consumes, applied in subset mode
because serving traces only the forward sites.  Add
``warm_cache_dir=`` to persist the transform cache across process
restarts (see :func:`repro.core.intercept.offload`).

Multi-device serving: pass ``mesh=`` to shard the engine across the
slot (batch) axis — parameters replicated, the KV cache and every
prefill/decode batch partitioned over the data-parallel axis, so each
dp group owns ``batch_slots / dp`` slots.  A 2-D ``dp×tp`` mesh
additionally shards the *parameters* per the LM axis rules
(:mod:`repro.shard.rules`) and the KV cache (dense rectangle or paged
pool alike) over ``tp`` on its kv-head axis.  The paged pool's block
axis is partitioned over dp — each dp group owns a contiguous block
range, including its own trash block, so allocation never crosses
shards.
"""

from __future__ import annotations

import time
from typing import List, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.core import PrecisionPolicy
from repro.models import Model
from repro.obs import MetricsServer, SLOTracker, get_logger
from repro.serve.kvcache import DenseKVCache, PagedKVCache
from repro.serve.runner import Runner
from repro.serve.scheduler import (Request, SamplingParamError,
                                   Scheduler)
from repro.shard import (TP_AXIS, data_parallel_sharding,
                         lm_param_specs, state_shardings, validate_tp)

__all__ = ["Engine", "Request", "SamplingParamError"]

log = get_logger("serve")


class Engine:
    """Continuous-batching engine (greedy by default, per-request
    temperature sampling on top).

    Args:
      model: the :class:`~repro.models.Model` (its config fixes the
        vocabulary and ``eos_id``).
      params: parameter pytree (trained or fresh).
      batch_slots: decode batch width = number of concurrent requests.
      max_len: KV-cache capacity per slot; a request finishes early if
        ``prompt + generated`` would outgrow it.
      mesh: optional :class:`jax.sharding.Mesh`; shards the slot axis
        over the data-parallel axis (``batch_slots`` must divide by
        the dp extent).  A 2-D ``dp×tp`` mesh also tp-shards the
        parameters and the KV cache's kv-head axis per the LM axis
        rules (``tp`` must divide ``num_kv_heads``).
      plan: optional :class:`repro.tune.PrecisionPlan` loaded at
        startup — the prefill and decode programs run through the
        automatic offload transform under the plan's policy, in subset
        mode (train-calibrated plans carry backward-pass sites that
        never appear here).
      policy: optional :class:`~repro.core.PrecisionPolicy` — same
        effect, explicit policy instead of a plan artifact (wins over
        ``plan`` for the transform configuration if both are given).
      metrics: optional :class:`repro.obs.MetricsRun` — per-request
        latency telemetry, queue-depth / block-utilization gauges,
        prefill/decode tracer spans, and (under a plan/policy) per-site
        GEMM execution counts, all streamed into the run's JSONL file.
      kv_layout: ``"paged"`` (default) or ``"dense"``.  Paged carves
        the cache into ``block_size``-token blocks allocated on demand
        through a per-slot block table; dense keeps the original
        per-slot ``max_len`` rectangle.  Both emit identical tokens.
      block_size: paged block granularity; ``max_len`` must divide by
        it.
      num_blocks: paged pool size in usable blocks (default: the dense
        equivalent, so admission never waits on blocks).  Smaller pools
        oversubscribe slots; admission then reserves worst-case growth
        so decoding requests cannot deadlock.
      chunk_tokens: prefill chunk length.  ``None`` (default) ingests
        each prompt in one piece (the pre-refactor behavior); set to
        e.g. 64 to interleave decode ticks into long-prompt ingestion.
      chunk_token_budget: cap on total real tokens per prefill wave
        (packing budget); ``None`` = unlimited.
      warm_cache_dir: directory for the persistent jaxpr-transform
        cache.  A restarted engine pointed at the same directory
        reuses the prior process's transform decisions (and compiled
        programs where exportable) without re-tracing.  Single-device,
        policy/plan runs only.
      scheduler_policy: ``"fifo"`` (default, the pre-refactor order)
        or ``"edf"`` (earliest ``t_enqueue + latency_target_s`` first).
      metrics_port: start a live :class:`repro.obs.MetricsServer` on
        this port (0 = ephemeral; read it back from
        ``engine.metrics_server.port``) serving the run's registry at
        ``/metrics`` while the engine runs.  Requires ``metrics=``.
      slo_objective / slo_window_s: the serve SLO — per-request TTFT
        vs ``latency_target_s`` feeds a rolling burn-rate gauge
        (``slo_burn_rate``) via :class:`repro.obs.SLOTracker`; only
        active with ``metrics=``.
    """

    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_len: int = 512, mesh=None, plan=None,
                 policy: Optional[PrecisionPolicy] = None,
                 metrics=None, *, kv_layout: str = "paged",
                 block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 chunk_tokens: Optional[int] = None,
                 chunk_token_budget: Optional[int] = None,
                 warm_cache_dir=None,
                 scheduler_policy: str = "fifo",
                 metrics_port: Optional[int] = None,
                 slo_objective: float = 0.99,
                 slo_window_s: float = 60.0):
        if kv_layout not in ("paged", "dense"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}; "
                             "have ('paged', 'dense')")
        self.model = model
        self.metrics = metrics
        self.batch_slots = int(batch_slots)
        self.max_len = int(max_len)
        self.mesh = mesh
        self._dp_size = 1
        slot_sharding = kv_sharding = None
        if mesh is not None:
            shape = dict(mesh.shape)
            tp = shape.get(TP_AXIS, 1)
            dp_axis = next((a for a in mesh.axis_names
                            if a != TP_AXIS), mesh.axis_names[0])
            self._dp_size = shape[dp_axis]
            if self.batch_slots % self._dp_size:
                raise ValueError(
                    f"batch_slots={self.batch_slots} is not divisible "
                    f"by the data-parallel extent {dp_axis}="
                    f"{self._dp_size}")
            # The canonical placements come from repro.shard; only the
            # KV layout is serve-specific, and the paged pool reuses
            # the dense spec: dim 1 is blocks instead of slots (the
            # per-dp-group block ranges keep it evenly divisible) and
            # dim 2 is still kv-heads for tp.
            if tp > 1:
                validate_tp(model.cfg, tp)
                params = jax.device_put(
                    params,
                    state_shardings(mesh, lm_param_specs(model.cfg)))
                slot_sharding = NamedSharding(
                    mesh, PartitionSpec(dp_axis))
                kv_sharding = NamedSharding(
                    mesh, PartitionSpec(None, dp_axis, TP_AXIS))
            else:
                replicated, slot_sharding = \
                    data_parallel_sharding(mesh, dp_axis)
                kv_sharding = NamedSharding(
                    mesh, PartitionSpec(None, dp_axis))
                params = jax.device_put(params, replicated)
        self.params = params
        if policy is None and plan is not None:
            # Unmatched-site handling must be silent: a train-
            # calibrated plan legitimately carries backward-pass
            # entries that no serve program contains.
            policy = PrecisionPolicy.from_plan(
                plan, on_unmatched_site="ignore")
        self.plan = plan
        self.policy = policy

        registry = metrics.registry if metrics is not None else None
        if kv_layout == "paged":
            self.kv = PagedKVCache(
                model, self.batch_slots, self.max_len,
                block_size=block_size, num_blocks=num_blocks,
                dp_groups=self._dp_size, registry=registry)
        else:
            self.kv = DenseKVCache(model, self.batch_slots,
                                   self.max_len, registry=registry)
        self.runner = Runner(
            model, params, self.kv, max_len=self.max_len, mesh=mesh,
            dp_size=self._dp_size, slot_sharding=slot_sharding,
            kv_sharding=kv_sharding, policy=policy, plan=plan,
            metrics=metrics, chunk_tokens=chunk_tokens,
            chunk_token_budget=chunk_token_budget,
            warm_cache_dir=warm_cache_dir)
        self.slo = None
        if metrics is not None:
            self.slo = SLOTracker(registry=metrics.registry,
                                  objective=slo_objective,
                                  window_s=slo_window_s,
                                  sink=metrics.sink)
        self.scheduler = Scheduler(self.max_len,
                                   policy=scheduler_policy,
                                   metrics=metrics, slo=self.slo)
        self.metrics_server = None
        if metrics_port is not None:
            if metrics is None:
                raise ValueError("metrics_port requires metrics= (the "
                                 "server exposes that run's registry)")
            self.metrics_server = MetricsServer(
                metrics.registry, port=metrics_port,
                runs_dir=metrics.directory).start()
        self.slots: List[Optional[Request]] = [None] * self.batch_slots
        self._next_token = np.zeros(self.batch_slots, np.int32)
        # Per-request latency bookkeeping, keyed by request identity
        # (Request is a plain mutable dataclass, not hashable by value).
        self._rstats: dict = {}

    # -- introspection -----------------------------------------------

    @property
    def cache(self) -> dict:
        """The live KV-cache pytree (owned by the runner)."""
        return self.runner.cache

    def prefill_sites(self, rows: int, width: int):
        """Site decisions of the prefill program for a wave of shape
        ``(rows, width)`` — what the offload transform would do, without
        executing anything.  Empty without a policy/plan."""
        return self.runner.sites_for(rows, width)

    # -- lifecycle ---------------------------------------------------

    def _admit(self) -> None:
        free = [i for i, r in enumerate(self.slots) if r is None]
        if not free or not self.scheduler.pending:
            return
        placed = self.scheduler.admit(
            free, lambda slot, req: self.kv.can_reserve(
                slot, len(req.prompt), req.max_new_tokens))
        if not placed and not any(r is not None for r in self.slots):
            # Idle engine, head of queue still unplaceable: its worst
            # case exceeds what an *empty* pool can book — waiting
            # cannot fix that.
            raise RuntimeError(
                "request can never be admitted: its worst-case cache "
                f"(prompt + max_new_tokens) outgrows the configured "
                f"pool ({self.kv.stats()}) — raise num_blocks")
        for slot, req in placed:
            self.kv.reserve(slot, len(req.prompt), req.max_new_tokens)
            self.slots[slot] = req
            self.runner.enqueue_prefill(slot, req)

    def _prefill_tick(self) -> None:
        res = self.runner.prefill_wave()
        if res is None:
            return
        if self.metrics is not None:
            log.debug(f"prefill wave: {len(res.pieces)} chunks, "
                      f"{res.real_tokens} tokens "
                      f"(padded {res.rows}x{res.width}) in "
                      f"{res.duration_s * 1e3:.1f} ms")
            t_wave = time.perf_counter() - res.duration_s
            for _, req, _ in res.pieces:
                st = self._rstats.get(id(req))
                if st is None:
                    continue
                if "t_admit" not in st:
                    # First chunk of this request to reach a device.
                    st["t_admit"] = t_wave
                    st["admission_wait_s"] = t_wave - st["t_enqueue"]
                    self.metrics.registry.histogram(
                        "serve_admission_wait_s").observe(
                        st["admission_wait_s"])
                st["prefill_s"] = (st.get("prefill_s", 0.0)
                                   + res.duration_s)
        for slot, req, token in res.completed:
            st = self._rstats.get(id(req))
            if st is not None:
                self.metrics.registry.histogram(
                    "serve_prefill_s").observe(st["prefill_s"])
            self._emit(slot, req, token)

    def _decode_tick(self) -> None:
        active = np.array([
            req is not None and not self.runner.is_prefilling(slot)
            for slot, req in enumerate(self.slots)])
        if not active.any():
            return
        if self.metrics is not None:
            self.metrics.registry.gauge("serve_slot_occupancy").set(
                int(active.sum()))
            for slot in np.flatnonzero(active):
                st = self._rstats.get(id(self.slots[slot]))
                if st is not None:
                    st["decode_ticks"] = st.get("decode_ticks", 0) + 1
        nxt = self.runner.decode_tick(self._next_token, active,
                                      self.slots)
        for slot in np.flatnonzero(active):
            self._emit(int(slot), self.slots[slot], int(nxt[slot]))

    def _emit(self, slot: int, req: Request, token: int) -> None:
        req.out.append(token)
        st = self._rstats.get(id(req))
        if st is not None and "ttft_s" not in st:
            # First emitted token (from the final prefill chunk).
            st["ttft_s"] = time.perf_counter() - st["t_enqueue"]
            self.metrics.registry.histogram(
                "serve_ttft_s").observe(st["ttft_s"])
            if req.latency_target_s is not None:
                slack = req.latency_target_s - st["ttft_s"]
                self.metrics.registry.histogram(
                    "serve_latency_slack_s").observe(slack)
                if slack < 0:
                    self.metrics.registry.counter(
                        "serve_latency_miss").inc()
            if self.slo is not None:
                self.slo.observe(st["ttft_s"], req.latency_target_s)
        self._next_token[slot] = token
        eos = self.model.cfg.eos_id
        length_next = len(req.prompt) + len(req.out)
        if (len(req.out) >= req.max_new_tokens
                or (eos is not None and token == eos)
                or length_next >= self.max_len):
            req.done = True
            self.slots[slot] = None
            self.kv.release(slot)
            self.scheduler.forget(req)
            if st is not None:
                self._finish(req, st)

    def _finish(self, req: Request, st: dict) -> None:
        """Finalize one request's telemetry: the ``request`` event."""
        gen_s = time.perf_counter() - st.get("t_admit",
                                             st["t_enqueue"])
        tokens_per_s = len(req.out) / max(gen_s, 1e-9)
        self.metrics.registry.counter("serve_tokens").inc(len(req.out))
        self.metrics.event(
            "request", prompt_len=len(req.prompt),
            new_tokens=len(req.out),
            admission_wait_s=st.get("admission_wait_s"),
            prefill_s=st.get("prefill_s"), ttft_s=st.get("ttft_s"),
            decode_ticks=st.get("decode_ticks", 0),
            tokens_per_s=tokens_per_s,
            latency_target_s=req.latency_target_s)
        log.debug(f"request done: {len(req.prompt)} prompt + "
                  f"{len(req.out)} new tokens, "
                  f"ttft {st.get('ttft_s', 0) * 1e3:.1f} ms, "
                  f"{tokens_per_s:.1f} tok/s")
        self._rstats.pop(id(req), None)

    # -- public API --------------------------------------------------

    def run(self, requests: List[Request]) -> List[Request]:
        """Drive all ``requests`` to completion; returns them in order.

        Requests are validated up front (:class:`SamplingParamError`,
        a ``ValueError``); admission follows the scheduler policy, and
        more requests than slots simply queue and are admitted as
        earlier ones finish.
        """
        self.scheduler.submit(requests)
        if self.metrics is not None:
            for req in requests:
                self._rstats[id(req)] = {
                    "t_enqueue": self.scheduler.t_enqueue(req)}
        while (self.scheduler.pending or
               any(r is not None for r in self.slots)):
            self._admit()
            self._prefill_tick()
            self._decode_tick()
        if self.metrics is not None:
            self.metrics.registry.gauge("serve_slot_occupancy").set(0)
            # Site-event callbacks (plan/policy runs) are async; drain
            # them so execution counters are complete at flush time.
            jax.effects_barrier()
        return requests

    def close(self) -> None:
        """Stop the live metrics server, if one was started."""
        if self.metrics_server is not None:
            self.metrics_server.close()
            self.metrics_server = None
