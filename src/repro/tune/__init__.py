"""repro.tune — precision-plan tuning: calibrate, solve, persist.

The paper's thesis is that emulation precision is a *per-operator*
knob; this package turns the knob-setting into a first-class offline
optimization with a persistable artifact:

* :mod:`repro.tune.calibrate` — :class:`Calibrator`, the instrumented
  pass that records per-site operand statistics and measured error
  (pmax-shared across mesh axes in sharded runs);
* :mod:`repro.tune.solve` — :func:`solve_plan`, the cost-optimal
  split assignment under a composed error budget, plus
  :func:`count_int8_gemms`, the cost metric;
* :mod:`repro.tune.plan` — :class:`PrecisionPlan`, the versioned,
  fingerprinted JSON artifact consumed by
  :meth:`repro.core.PrecisionPolicy.from_plan` and
  ``offload(fn, plan=...)``;
* :mod:`repro.tune.cli` — the ``python -m repro.tune`` flow
  (``launch/train.py --tune`` runs the same calibrate-and-solve
  inline).
"""

from .calibrate import CalibrationResult, Calibrator, SiteRecord
from .plan import (PLAN_VERSION, PlanError, PlanSite, PlanStaleError,
                   PrecisionPlan, site_set_fingerprint)
from .solve import (count_int8_gemms, default_budget, solve_plan,
                    unpinned_family)

__all__ = [
    "PLAN_VERSION",
    "CalibrationResult",
    "Calibrator",
    "PlanError",
    "PlanSite",
    "PlanStaleError",
    "PrecisionPlan",
    "SiteRecord",
    "count_int8_gemms",
    "default_budget",
    "site_set_fingerprint",
    "solve_plan",
    "unpinned_family",
]
