"""Entry point: ``python -m repro.tune`` (see :mod:`repro.tune.cli`)."""

from .cli import main

if __name__ == "__main__":
    main()
