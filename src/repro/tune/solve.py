"""Cost-optimal per-site split solving.

The solver turns a :class:`~repro.tune.calibrate.CalibrationResult`
into a :class:`~repro.tune.plan.PrecisionPlan`: given an end-to-end
relative-error budget, assign each site the split count that minimizes
the modeled emulation cost

    cost(s_i) = split_cost(s_i) * flops_i

where ``split_cost`` is the analytic kernel model's pair-schedule cost
(:func:`repro.kernels.tile_model.split_cost` — the s(s+1)/2 INT8
pair-GEMMs of the schedule plus the O(s) slice-array traffic each
extra split streams, in pair-GEMM units), subject to the composed
(first-order additive) error bound

    sum_i  err_i(s_i)  <=  budget.

Per-site error curves are *calibrated*: the a-priori model
``4 sqrt(k) 2**(-w s)`` (:func:`repro.core.precision.estimate_rel_error`)
deliberately over-estimates, so where calibration measured the actual
probe error the curve is anchored there and extrapolated geometrically
(one split buys exactly ``slice_bits`` mantissa bits).  This is the
mechanism behind the paper's pitch: a uniform split count sized by the
worst-case model pays for mantissa bits most sites never need, while
the calibrated solve hits the same end-to-end tolerance with fewer
INT8 GEMMs.

Sites whose measured error *exceeds* the model by ``demote_ratio``
(operands the Ozaki row/column scaling cannot represent well) are
demoted to the native ``dgemm`` backend — emulating them at any split
count would poison the budget.

The assignment itself is greedy marginal analysis — repeatedly grant
one extra split to the site with the best error-reduction per unit
cost — which is near-optimal here because each split cuts a site's
error by the huge constant ``2**slice_bits`` while cost grows only
linearly in ``s``.  Ties break on the site name, and the cost model
uses only dp-invariant inputs (``flops`` is shard-summed,
``split_cost`` depends on ``s`` alone), so the solve is deterministic
given identical inputs (the dp=8 == single-device byte-identity relies
on this).  For Pallas-family plans each solved site also records the
tile model's canonical block pick — again from ``(k, dtype, splits)``
only, never per-shard geometry.
"""

from __future__ import annotations

import math
from typing import Dict, Iterable, Optional

import jax.numpy as jnp

from repro.core.backends import _SPLITS_RE
from repro.core.intercept import Site
from repro.core.ozaki import num_pair_gemms
from repro.core.precision import MAX_SPLITS, estimate_rel_error
from repro.kernels.tile_model import select_tiles, split_cost

from .calibrate import CalibrationResult, SiteRecord
from .plan import PlanSite, PrecisionPlan

__all__ = ["solve_plan", "default_budget", "count_int8_gemms",
           "unpinned_family"]


def unpinned_family(spec: str) -> str:
    """Strip a pinned split count from a backend spec.

    ``"fp64_int8_6" -> "fp64_int8"``.  A plan owns the per-site split
    counts, so the policy it reconstructs must carry the *family* spec
    — a pinned spec would be authoritative and override the plan.
    """
    head, sep, arg = spec.partition(":")
    m = _SPLITS_RE.fullmatch(head)
    if m:
        head = m.group("family")
    return head + (sep + arg if sep else "")


def _plan_tiles(family: str, k: int, dtype: str, splits: int):
    """Canonical tile pick recorded in a PlanSite (Pallas families only).

    Derived from ``(k, dtype, splits)`` alone — free extents are
    per-shard and would break the dp=N == single-device plan
    byte-identity.  The runtime backend re-selects with the true
    geometry; this is the reviewable record of the decision.
    """
    if not family.startswith("pallas_int8"):
        return None
    d = select_tiles(None, k, None, splits, dtype=dtype,
                     fused=family.endswith(":fused"))
    return (d.block_m, d.block_n, d.block_k)


def default_budget(records: Iterable[SiteRecord],
                   scale: float = 32.0) -> float:
    """End-to-end error budget derived from the site dtypes.

    Emulating tighter than the strictest participating dtype can
    represent buys nothing: the default budget is ``scale`` times that
    dtype's machine epsilon (32 ulps of headroom for the composed
    bound's slack), e.g. ~3.8e-6 for a float32 model and ~7.1e-15 for
    float64.
    """
    records = list(records)
    # A mixed f32/f64 program is bounded end-to-end by its lowest-
    # precision parts: budget to the *largest* participating eps.
    # jnp.finfo, not np.finfo: it also resolves the ml_dtypes types
    # ("bfloat16") that np.finfo rejects.
    eps = max(float(jnp.finfo(jnp.dtype(r.dtype)).eps)
              for r in records) \
        if records else float(jnp.finfo(jnp.float32).eps)
    return float(scale * eps)


def _site_err(rec: SiteRecord, splits: int, slice_bits: int) -> float:
    """Calibrated error curve: measured probe anchored, else a-priori."""
    model = estimate_rel_error(splits, rec.k, slice_bits)
    if rec.measured_rel is None:
        return model
    anchored = max(rec.measured_rel, 1e-30) * \
        2.0 ** (slice_bits * (rec.probe_splits - splits))
    # The anchor refines the model downward (the model is deliberately
    # conservative); a measurement *above* the model marks a
    # pathological site, which demotion handles — never let it push
    # the curve above the a-priori bound.
    return min(model, anchored)


def solve_plan(result: CalibrationResult, *,
               budget: Optional[float] = None,
               demote_ratio: float = 100.0,
               max_splits: int = MAX_SPLITS) -> PrecisionPlan:
    """Solve the per-site split assignment and build the plan.

    Args:
      result: calibration output (site records + fingerprint).
      budget: end-to-end relative-error budget; default
        :func:`default_budget` of the calibrated dtypes.
      demote_ratio: a site measured worse than ``demote_ratio`` times
        its a-priori model at the probe split count is demoted to
        ``dgemm``.
      max_splits: per-site ceiling; if the budget is unreachable even
        at the ceiling the plan is still produced with
        ``budget_met=False``.
    """
    policy = result.policy
    slice_bits = policy.slice_bits
    records = list(result.records)
    if budget is None:
        budget = default_budget(records)
    budget = float(budget)
    if budget <= 0:
        raise ValueError(f"budget must be positive, got {budget}")

    family = unpinned_family(policy.backend)
    demoted: Dict[str, SiteRecord] = {}
    tunable: Dict[str, SiteRecord] = {}
    for rec in records:
        model = estimate_rel_error(rec.probe_splits, rec.k, slice_bits)
        if (rec.measured_rel is not None
                and rec.measured_rel > demote_ratio * model):
            demoted[rec.site] = rec
        else:
            tunable[rec.site] = rec

    # Greedy marginal analysis, deterministic: everything starts at
    # one split; each round grants one split to the site with the best
    # error-drop per added INT8 FLOP, until the composed bound meets
    # the budget (or every site hits the ceiling).
    splits = {name: 1 for name in tunable}
    errs = {name: _site_err(rec, 1, slice_bits)
            for name, rec in tunable.items()}
    total = math.fsum(errs.values())
    while total > budget:
        best_name, best_gain = None, -1.0
        for name, rec in sorted(tunable.items()):
            s = splits[name]
            if s >= max_splits:
                continue
            drop = errs[name] - _site_err(rec, s + 1, slice_bits)
            # Marginal cost from the kernel model's pair-schedule
            # curve, not the bare n_pairs(s) proxy: the extra pair
            # GEMMs of s+1 plus the extra slice layer it streams.
            cost = (split_cost(s + 1) - split_cost(s)) \
                * max(rec.flops, 1)
            gain = drop / cost
            if gain > best_gain:
                best_name, best_gain = name, gain
        if best_name is None:
            break  # every tunable site is at the ceiling
        splits[best_name] += 1
        new_err = _site_err(tunable[best_name], splits[best_name],
                            slice_bits)
        total += new_err - errs[best_name]
        errs[best_name] = new_err

    sites = []
    for name, rec in tunable.items():
        sites.append(PlanSite(
            site=name, k=rec.k, dtype=rec.dtype, flops=rec.flops,
            lhs_exp=rec.lhs_exp or 0, rhs_exp=rec.rhs_exp or 0,
            splits=splits[name], backend=family,
            tiles=_plan_tiles(family, rec.k, rec.dtype, splits[name])))
    for name, rec in demoted.items():
        sites.append(PlanSite(
            site=name, k=rec.k, dtype=rec.dtype, flops=rec.flops,
            lhs_exp=rec.lhs_exp or 0, rhs_exp=rec.rhs_exp or 0,
            splits=0, backend="dgemm"))

    return PrecisionPlan(
        fingerprint=result.fingerprint,
        backend=family,
        accumulator=policy.accumulator,
        slice_bits=slice_bits,
        min_dim=policy.min_dim,
        budget=budget,
        budget_met=total <= budget,
        probe_splits=result.probe_splits,
        sites=tuple(sites))


def count_int8_gemms(sites: Iterable[Site],
                     splits_for=None) -> int:
    """Per-step INT8 GEMM count of a site-decision list.

    Sums, over offloaded sites, the Ozaki pair count ``s(s+1)/2``
    times the batch extent, the static trip multiplicity (enclosing
    ``scan`` lengths), and 4 for complex sites (the four-real-GEMM
    decomposition).  The comparison metric the paper story rests on:
    a tuned plan must beat uniform splits here at equal accuracy.
    Counts are per shard — mesh axes multiply GEMM instances across
    devices, not per-device work — so compare like against like.

    ``splits_for(site) -> int | None`` overrides each site's recorded
    split count (``None`` = the site runs native and contributes 0),
    which lets one traced site list be costed under several
    assignments — e.g. a solved plan vs the uniform policy it was
    calibrated with — without re-tracing the program.
    """
    total = 0
    for site in sites:
        if not site.offloaded:
            continue
        s = site.splits if splits_for is None else splits_for(site)
        if s is None:
            continue
        cplx = 4 if jnp.issubdtype(jnp.dtype(site.dtype),
                                   jnp.complexfloating) else 1
        total += (num_pair_gemms(s) * max(site.batch, 1)
                  * site.mult * cplx)
    return total
