"""PrecisionPlan: the persistable per-site tuning artifact.

A plan is the output of ``calibrate -> solve`` (see
:mod:`repro.tune.calibrate` / :mod:`repro.tune.solve`): one record per
eligible GEMM site carrying the solved split count and backend, plus
the policy-level numerics (backend family, accumulator, slice bits,
size gate) — everything :meth:`repro.core.PrecisionPolicy.from_plan`
needs to reconstruct the execution configuration.  It is versioned
JSON with a **site-set fingerprint** so staleness is detected instead
of silently mis-tuning:

* the fingerprint hashes the *canonical* site set — SPMD scopes
  (``shmap0/``, ``pmap0/``) stripped from names, and only the
  contraction extent ``k`` + dtype of each site, never the free
  extents — so the same program calibrated under a ``dp=N`` mesh and
  on a single device fingerprints (and serializes) identically, and a
  plan survives batch-size changes (which move ``m``, not ``k``);
* :meth:`PrecisionPlan.validate_sites` recomputes the fingerprint from
  a freshly traced site set and raises :class:`PlanStaleError` naming
  the added/removed sites when the program drifted (new layer, changed
  width, different architecture).

Serialization is deliberately deterministic — sorted keys, sorted
sites, integers and short strings only — so two calibration runs of
the same configuration produce byte-identical files (the dp=8 vs
single-device equivalence the tests assert).  Timestamps, hostnames
and measured floating-point diagnostics are intentionally *not*
persisted.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Tuple

import jax.numpy as jnp

from repro.core.precision import canonical_site

__all__ = [
    "PLAN_VERSION",
    "PlanError",
    "PlanStaleError",
    "PlanSite",
    "PrecisionPlan",
    "site_set_fingerprint",
    "tiles_table",
    "write_tiles_table",
]

#: Schema version of the JSON artifact; bump on breaking layout change.
PLAN_VERSION = 1


class PlanError(RuntimeError):
    """A plan file is malformed, missing, or from an unknown version."""


class PlanStaleError(PlanError):
    """The traced site set no longer matches the plan's fingerprint."""


def site_set_fingerprint(sites) -> str:
    """Fingerprint of the *eligible* site set of a traced function.

    ``sites`` are :class:`repro.core.Site` records (from
    ``offload(...).sites(...)``/``site_report``) or :class:`PlanSite`
    entries.  Only sites that pass the dtype/size gates count — a
    plan-demoted site is still eligible, so demotion never changes the
    fingerprint — and each contributes its canonical name, contraction
    extent and dtype.
    """
    entries = set()
    for s in sites:
        if not getattr(s, "eligible", True):
            continue
        name = canonical_site(getattr(s, "name", None) or s.site)
        dtype = jnp.dtype(s.dtype).name
        entries.add(f"{name}|k={int(s.k)}|{dtype}")
    digest = hashlib.sha256("\n".join(sorted(entries)).encode()).hexdigest()
    return f"sha256:{digest[:16]}"


@dataclasses.dataclass(frozen=True)
class PlanSite:
    """One solved site: the tuning decision plus its solver inputs.

    ``flops`` is the per-step FLOP volume summed over mesh shards and
    scan iterations (the cost-model weight); ``lhs_exp``/``rhs_exp``
    are the calibrated operand max-abs exponents
    (``ceil(log2(max|X|))``, pmax-shared across the mesh in sharded
    calibration runs).  ``backend == "dgemm"`` demotes the site to
    native execution.

    ``tiles`` is the analytic tile model's *canonical* block pick
    ``(block_m, block_n, block_k)`` for Pallas-family sites (``None``
    otherwise, and in plans written before the model existed).
    Canonical means derived from ``(k, dtype, splits)`` only — never
    from per-shard free extents — so a plan solved under a ``dp=N``
    mesh stays byte-identical to a single-device one.  The runtime
    backend re-derives the final blocks from the site's true geometry;
    the plan records the decision for reports and regression tracking.
    """

    site: str
    k: int
    dtype: str
    flops: int
    lhs_exp: int
    rhs_exp: int
    splits: int
    backend: str
    tiles: Tuple[int, int, int] | None = None

    #: ``site_set_fingerprint`` treats every PlanSite as eligible.
    eligible = True

    def __post_init__(self):
        if self.tiles is not None:
            object.__setattr__(self, "tiles", tuple(self.tiles))


@dataclasses.dataclass
class PrecisionPlan:
    """The versioned per-site precision configuration artifact."""

    fingerprint: str
    backend: str
    accumulator: str
    slice_bits: int
    min_dim: int
    budget: float
    budget_met: bool
    probe_splits: int
    sites: Tuple[PlanSite, ...]
    version: int = PLAN_VERSION

    def __post_init__(self):
        self.sites = tuple(sorted(self.sites, key=lambda s: s.site))

    # -- derived views ------------------------------------------------

    def site_splits(self) -> dict:
        """Canonical-site -> split-count map (demoted sites excluded)."""
        return {s.site: s.splits for s in self.sites
                if s.backend != "dgemm"}

    def demoted_sites(self) -> list:
        return sorted(s.site for s in self.sites if s.backend == "dgemm")

    def describe(self) -> str:
        lines = [f"PrecisionPlan {self.fingerprint} "
                 f"(v{self.version}, backend={self.backend}, "
                 f"budget={self.budget:.2e}"
                 f"{'' if self.budget_met else ' NOT MET'})"]
        for s in self.sites:
            action = ("dgemm (demoted)" if s.backend == "dgemm"
                      else f"s={s.splits}")
            if s.tiles:
                action += " tiles={}x{}x{}".format(*s.tiles)
            lines.append(f"  {s.site}: k={s.k} {s.dtype} "
                         f"flops={s.flops:.3g} -> {action}")
        return "\n".join(lines)

    # -- staleness ----------------------------------------------------

    def validate_sites(self, sites) -> None:
        """Raise :class:`PlanStaleError` if ``sites`` drifted.

        ``sites`` is a freshly traced site list; the comparison is on
        the canonical fingerprint, and the error message names the
        site entries that appeared/disappeared so the fix ("re-tune")
        is obvious.
        """
        current = site_set_fingerprint(sites)
        if current == self.fingerprint:
            return
        planned = {f"{s.site}(k={s.k},{s.dtype})" for s in self.sites}
        traced = {f"{canonical_site(s.name)}(k={s.k},"
                  f"{jnp.dtype(s.dtype).name})"
                  for s in sites if getattr(s, "eligible", True)}
        raise PlanStaleError(
            f"plan fingerprint {self.fingerprint} does not match the "
            f"traced site set ({current}): the program changed since "
            f"calibration. Sites only in plan: "
            f"{sorted(planned - traced) or '[]'}; only in trace: "
            f"{sorted(traced - planned) or '[]'}. Re-run calibration "
            "(launch/train.py --tune / python -m repro.tune) to "
            "refresh the plan.")

    # -- (de)serialization --------------------------------------------

    def to_json(self) -> str:
        """Deterministic JSON: byte-identical for identical plans."""
        doc = {
            "version": self.version,
            "fingerprint": self.fingerprint,
            "backend": self.backend,
            "accumulator": self.accumulator,
            "slice_bits": self.slice_bits,
            "min_dim": self.min_dim,
            "budget": self.budget,
            "budget_met": self.budget_met,
            "probe_splits": self.probe_splits,
            "sites": [dataclasses.asdict(s) for s in self.sites],
        }
        return json.dumps(doc, indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "PrecisionPlan":
        try:
            doc = json.loads(text)
        except json.JSONDecodeError as e:
            raise PlanError(f"plan is not valid JSON: {e}") from None
        if not isinstance(doc, dict):
            raise PlanError(f"plan must be a JSON object, got "
                            f"{type(doc).__name__}")
        version = doc.get("version")
        if version != PLAN_VERSION:
            raise PlanError(
                f"plan version {version!r} is not supported (this "
                f"build reads version {PLAN_VERSION}); re-run "
                "calibration to regenerate it")
        required = ["fingerprint", "backend", "accumulator",
                    "slice_bits", "min_dim", "budget", "budget_met",
                    "probe_splits", "sites"]
        missing = [kk for kk in required if kk not in doc]
        if missing:
            raise PlanError(f"plan is missing required keys: {missing}")
        try:
            sites = tuple(PlanSite(**s) for s in doc["sites"])
        except TypeError as e:
            raise PlanError(f"malformed plan site entry: {e}") from None
        return cls(fingerprint=doc["fingerprint"],
                   backend=doc["backend"],
                   accumulator=doc["accumulator"],
                   slice_bits=int(doc["slice_bits"]),
                   min_dim=int(doc["min_dim"]),
                   budget=float(doc["budget"]),
                   budget_met=bool(doc["budget_met"]),
                   probe_splits=int(doc["probe_splits"]),
                   sites=sites,
                   version=int(version))

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json())
        return path

    @classmethod
    def load(cls, path) -> "PrecisionPlan":
        path = Path(path)
        if not path.exists():
            raise PlanError(f"no precision plan at {path}")
        return cls.from_json(path.read_text())


def tiles_table(plan: PrecisionPlan) -> dict:
    """Tile-model decision table for a plan's Pallas-family sites.

    One row per site that carries a tile pick, with the analytic
    figures behind the decision (VMEM footprint, MXU issue cycles and
    HBM bytes per grid step, pair-schedule length) recomputed from the
    same canonical inputs the solver used — so the artifact CI uploads
    next to the plan JSON makes tile-selection regressions reviewable,
    not just split counts.  Deterministic like the plan itself.
    """
    from repro.kernels import tile_model  # no Pallas dependency

    rows = []
    for s in plan.sites:
        if not s.tiles or s.splits < 1:
            continue
        fused = s.backend.endswith(":fused")
        d = tile_model.select_tiles(None, s.k, None, s.splits,
                                    dtype=s.dtype, fused=fused)
        rows.append({
            "site": s.site, "k": s.k, "dtype": s.dtype,
            "backend": s.backend, "splits": s.splits,
            "tiles": list(s.tiles), "pairs": d.pairs,
            "schedule": d.schedule, "fused": fused,
            "vmem_bytes": d.vmem_bytes,
            "mxu_cycles_step": d.mxu_cycles_step,
            "hbm_bytes_step": d.hbm_bytes_step,
        })
    return {"fingerprint": plan.fingerprint, "backend": plan.backend,
            "sites": rows}


def write_tiles_table(plan: PrecisionPlan, plan_path) -> Path:
    """Write the tile-decision table next to the plan JSON.

    ``runs/plans/tiny.json`` gets ``runs/plans/tiny.tiles.json`` — the
    sibling artifact the CI workflow uploads with the plan.
    """
    path = Path(plan_path)
    path = path.with_name(path.stem + ".tiles.json")
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(tiles_table(plan), indent=2,
                               sort_keys=True) + "\n")
    return path
