"""Calibration: an instrumented pass that measures every GEMM site.

``Calibrator`` wraps a function exactly the way
:func:`repro.core.intercept.offload` does — same jaxpr walk, same
structural site names, same size/dtype gates — but routes every
eligible site through a *recording* backend instead of an execution
engine.  For each site call the backend:

* computes the native (``dgemm``) product — calibration output is the
  reference result, so a calibration step never perturbs training
  state;
* measures the relative error of the Ozaki emulation at a probe split
  count against that reference (normalized by ``|A| @ |B|``, the same
  convention as :func:`repro.core.precision.measure_splits`);
* records per-operand max-abs statistics.

Inside ``shard_map``/``pmap`` bodies the statistics are ``pmax``-shared
across the enclosing mesh axes *before* they leave the device, so
every shard records the same global numbers and a sharded calibration
run agrees with a single-device run on one plan.  The values reach the
host through ``jax.debug.callback`` — which fires inside ``scan`` /
``while`` / ``cond`` bodies too, so deeply nested sites are measured
per iteration and max-aggregated.

The result (:class:`CalibrationResult`) carries one
:class:`SiteRecord` per eligible site, keyed by the *canonical* site
name (SPMD scopes stripped), with the dp-invariant solver inputs:
contraction extent, dtype, per-step FLOPs (summed over shards and
scan trips), operand max-abs exponents, and the measured probe error
(quantized to two significant digits so mesh-layout ulp noise cannot
leak into solver decisions).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backends import GemmBackend
from repro.core.intercept import Site, offload
from repro.core.ozaki import ozaki_matmul
from repro.core.precision import PrecisionPolicy, canonical_site

from .plan import site_set_fingerprint

__all__ = ["Calibrator", "CalibrationResult", "SiteRecord"]


def _quantize(x: float, digits: int = 2) -> float:
    """Round to ``digits`` significant decimal digits.

    Calibration statistics cross mesh layouts: per-shard partial
    products can differ from the single-device computation in final
    ulps (different GEMM tilings), and solver inputs must not.  Two
    significant digits keeps the error magnitude (all the solver
    needs) while burying ulp noise ~14 orders of magnitude below the
    quantization step.
    """
    if x == 0.0 or not np.isfinite(x):
        return float(x)
    from math import floor, log10
    scale = 10.0 ** (digits - 1 - floor(log10(abs(x))))
    return round(x * scale) / scale


@dataclasses.dataclass
class SiteRecord:
    """Calibrated statistics for one eligible GEMM site."""

    site: str            #: canonical site name (SPMD scopes stripped)
    k: int               #: contraction extent (merged)
    dtype: str           #: result dtype name
    flops: int           #: per-step FLOPs across shards & scan trips
    probe_splits: int    #: split count the error probe ran at
    lhs_exp: Optional[int] = None   #: ceil(log2(max|A|)), None if unseen
    rhs_exp: Optional[int] = None   #: ceil(log2(max|B|))
    measured_rel: Optional[float] = None  #: probe error, 2 sig. digits
    calls: int = 0       #: host callback invocations (diagnostic only)
    #: canonical (k-only) tile-model pick at the probe split count for
    #: Pallas-family policies, ``(block_m, block_n, block_k)``;
    #: diagnostic — the solver re-derives tiles at the *solved* count.
    tiles: Optional[Tuple[int, int, int]] = None


class _Recorder:
    """Thread-safe max-aggregating sink for the device callbacks."""

    def __init__(self):
        self._lock = threading.Lock()
        self._stats: Dict[str, Dict[str, float]] = {}

    def record(self, site: str, err, amax_l, amax_r) -> None:
        # The callback may run on the runtime's callback thread while
        # the device is blocked inside the calling computation:
        # launching any jax op here (np.max on a jax.Array dispatches
        # jnp.max!) deadlocks the single-threaded CPU runtime.  Pure
        # host transfers first, numpy-only reductions after.
        #
        # Under vmap the callback may deliver batched arrays; under a
        # mesh it fires once per device with identical (pmax-shared)
        # values — max + max-merge handles both, idempotently.
        err = float(np.max(np.asarray(err)))
        amax_l = float(np.max(np.asarray(amax_l)))
        amax_r = float(np.max(np.asarray(amax_r)))
        with self._lock:
            st = self._stats.setdefault(
                site, {"err": 0.0, "al": 0.0, "ar": 0.0, "calls": 0})
            st["err"] = max(st["err"], err)
            st["al"] = max(st["al"], amax_l)
            st["ar"] = max(st["ar"], amax_r)
            st["calls"] += 1

    def get(self, site: str) -> Optional[Dict[str, float]]:
        with self._lock:
            st = self._stats.get(site)
            return dict(st) if st is not None else None


class _CalibrationGemm(GemmBackend):
    """Recording backend: native result out, statistics to the host."""

    #: The offload transform skips the custom_vjp wrapper for this
    #: backend: debug-callback effects cannot be staged through
    #: custom_vjp, and calibration output is never differentiated.
    supports_vjp = False
    #: Every eligible site routes through this backend, overriding any
    #: per-site ``site_backends`` spec — calibration instruments the
    #: whole program.
    intercepts_all_sites = True

    def __init__(self, policy: PrecisionPolicy, probe_splits: int,
                 recorder: _Recorder):
        super().__init__("calibrate", policy)
        self.probe_splits = int(probe_splits)
        self.recorder = recorder
        self._meta: Dict[str, Site] = {}
        #: per-site measurement floor: below ~64 ulps of the reference
        #: dtype a probe error is reference noise, not signal (set at
        #: trace time — the floor is static per site).
        self.floors: Dict[str, float] = {}

    def observe_sites(self, decisions: Dict[str, Site]) -> None:
        # transform_jaxpr hands over the full Site records before the
        # trace starts; matmul() only receives the site *name* and
        # needs the enclosing SPMD axes to pmax the statistics.
        self._meta.update(decisions)

    def matmul(self, a, b, *, out_dtype=None, num_splits=None,
               site: str = "default"):
        del num_splits  # the probe split count is fixed per pass
        meta = self._meta.get(site)
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        native = a @ b

        is_cplx = (jnp.issubdtype(a.dtype, jnp.complexfloating)
                   or jnp.issubdtype(b.dtype, jnp.complexfloating))
        ref_dtype = jnp.complex128 if is_cplx else jnp.float64
        if not jax.config.jax_enable_x64:
            ref_dtype = jnp.complex64 if is_cplx else jnp.float32
        floor = 64.0 * float(np.finfo(np.dtype(ref_dtype)).eps)
        self.floors[site] = max(self.floors.get(site, 0.0), floor)
        ref = jnp.matmul(a.astype(ref_dtype), b.astype(ref_dtype))
        emul = ozaki_matmul(a, b, num_splits=self.probe_splits,
                            accumulator=self.policy.accumulator,
                            out_dtype=ref_dtype,
                            slice_bits=self.policy.slice_bits)
        denom = jnp.abs(a).astype(jnp.abs(ref).dtype) @ \
            jnp.abs(b).astype(jnp.abs(ref).dtype)
        denom = jnp.where(denom == 0, 1.0, denom)
        err = jnp.max(jnp.abs(emul - ref) / denom)
        amax_l = jnp.max(jnp.abs(a))
        amax_r = jnp.max(jnp.abs(b))
        # Share the statistics across the mesh *inside* the SPMD scope
        # so every device reports identical global values — this is
        # what makes a dp=N calibration agree with a single-device one.
        for axis, _ in (meta.spmd_axes if meta is not None else ()):
            err = jax.lax.pmax(err, axis)
            amax_l = jax.lax.pmax(amax_l, axis)
            amax_r = jax.lax.pmax(amax_r, axis)

        def tap(e, al, ar, _site=site):
            self.recorder.record(_site, e, al, ar)

        jax.debug.callback(tap, err, amax_l, amax_r)
        return (native if out_dtype is None
                else native.astype(out_dtype))


def _exp_of(amax: float) -> Optional[int]:
    if amax <= 0:
        return 0
    return int(np.ceil(np.log2(amax)))


@dataclasses.dataclass
class CalibrationResult:
    """Everything the plan solver consumes."""

    records: List[SiteRecord]
    fingerprint: str
    policy: PrecisionPolicy
    probe_splits: int
    #: raw (non-canonical) site names that were eligible, for reports
    site_names: Tuple[str, ...] = ()

    def describe(self) -> str:
        lines = [f"Calibration: {len(self.records)} eligible sites, "
                 f"probe s={self.probe_splits}, "
                 f"fingerprint {self.fingerprint}"]
        for r in sorted(self.records, key=lambda r: r.site):
            err = ("unmeasured" if r.measured_rel is None
                   else f"err~{r.measured_rel:.1e}")
            tiles = (" tiles={}x{}x{}".format(*r.tiles)
                     if r.tiles else "")
            lines.append(
                f"  {r.site}: k={r.k} {r.dtype} flops={r.flops:.3g} "
                f"exp=({r.lhs_exp},{r.rhs_exp}) {err}{tiles}")
        return "\n".join(lines)


class Calibrator:
    """Run instrumented passes over ``fn`` and collect site statistics.

    Usage::

        cal = Calibrator(train_step, policy)
        for batch in batches:
            cal.run(params, opt_state, batch)   # returns native output
        result = cal.result()
        plan = solve_plan(result)

    ``run`` executes ``fn`` with every eligible GEMM site instrumented
    (native results, so the pass is side-effect-free for the caller);
    repeated calls aggregate statistics by max.  The site set is fixed
    by the first signature; a later signature with a *different*
    eligible site set raises — one plan covers one program.
    """

    def __init__(self, fn, policy: Optional[PrecisionPolicy] = None,
                 *, probe_splits: Optional[int] = None):
        self.fn = fn
        self.policy = policy or PrecisionPolicy()
        self.probe_splits = int(probe_splits
                                if probe_splits is not None
                                else self.policy.default_splits)
        self._recorder = _Recorder()
        self._gemm = _CalibrationGemm(self.policy, self.probe_splits,
                                      self._recorder)
        # The exact offload wrapper/cache machinery, with the
        # recording backend injected as the (authoritative) engine.
        self._wrapped = offload(fn, self.policy, backend=self._gemm)
        self._sites: Optional[List[Site]] = None
        self._fingerprint: Optional[str] = None

    def run(self, *args, **kwargs):
        """One instrumented pass; returns ``fn``'s (native) output."""
        out = self._wrapped(*args, **kwargs)
        # Debug callbacks are asynchronous: drain them before the
        # recorder is read (or the next pass starts).
        jax.effects_barrier()
        sites = self._wrapped.sites(*args, **kwargs)  # cached
        fp = site_set_fingerprint(sites)
        if self._fingerprint is None:
            self._fingerprint = fp
            self._sites = sites
        elif fp != self._fingerprint:
            raise ValueError(
                "calibration signatures disagree on the eligible "
                f"site set ({fp} vs {self._fingerprint}); "
                "calibrate one program shape per plan")
        return out

    @property
    def sites(self) -> Optional[List[Site]]:
        """Site decisions of the calibrated program (after first run).

        The same (cached) records ``offload(...).sites`` would return
        for the calibration policy — consumers cost alternative split
        assignments against them (:func:`~repro.tune.count_int8_gemms`
        with ``splits_for``) without re-tracing.
        """
        return self._sites

    def _probe_tiles(self, k: int, dtype: str):
        """Canonical tile pick at the probe split count (Pallas only)."""
        spec = self.policy.backend
        if not spec.startswith("pallas_int8"):
            return None
        from repro.kernels import tile_model  # no Pallas dependency

        d = tile_model.select_tiles(None, k, None, self.probe_splits,
                                    dtype=dtype,
                                    fused=spec.endswith(":fused"))
        return (d.block_m, d.block_n, d.block_k)

    def result(self) -> CalibrationResult:
        """Aggregate the recorded statistics into solver inputs.

        Sites are merged by canonical name: the ``shmap0/scan0/dot1``
        of a sharded run and the ``scan0/dot1`` of a single-device run
        produce the same record.  A canonical collision between sites
        with *different* contraction extents or dtypes is ambiguous
        and raises.
        """
        if self._sites is None:
            raise ValueError("no calibration pass has run yet")
        by_canon: Dict[str, SiteRecord] = {}
        names = []
        for site in self._sites:
            if not site.eligible:
                continue
            names.append(site.name)
            canon = canonical_site(site.name)
            rec = by_canon.get(canon)
            if rec is None:
                rec = by_canon[canon] = SiteRecord(
                    site=canon, k=site.k, dtype=site.dtype.name,
                    flops=0, probe_splits=self.probe_splits,
                    tiles=self._probe_tiles(site.k, site.dtype.name))
            elif (rec.k, rec.dtype) != (site.k, site.dtype.name):
                raise ValueError(
                    f"sites {site.name!r} and an earlier one share "
                    f"the canonical name {canon!r} but disagree on "
                    f"k/dtype ({site.k}/{site.dtype.name} vs "
                    f"{rec.k}/{rec.dtype}); cannot key one plan "
                    "entry on both")
            rec.flops += site.flops
            st = self._recorder.get(site.name)
            if st is not None:
                floor = self._gemm.floors.get(site.name, 0.0)
                if st["al"] > 0 and st["ar"] > 0 and st["err"] > floor:
                    # Two degenerate measurements stay on the a-priori
                    # model curve instead of anchoring it: a zero
                    # operand (the zero-initialized LM head at step 0)
                    # measures error 0 and would under-split the site
                    # once it trains away from zero; and a probe at or
                    # below the reference dtype's noise floor (~64
                    # ulps — f32 references when x64 is off) measures
                    # the reference, not the emulation, and would
                    # both mis-anchor and fake a pathological site.
                    rec.measured_rel = _quantize(max(
                        st["err"], rec.measured_rel or 0.0))
                rec.lhs_exp = max(_exp_of(st["al"]), rec.lhs_exp
                                  if rec.lhs_exp is not None else -(2**30))
                rec.rhs_exp = max(_exp_of(st["ar"]), rec.rhs_exp
                                  if rec.rhs_exp is not None else -(2**30))
                rec.calls += int(st["calls"])
        return CalibrationResult(
            records=sorted(by_canon.values(), key=lambda r: r.site),
            fingerprint=self._fingerprint,
            policy=self.policy,
            probe_splits=self.probe_splits,
            site_names=tuple(names))
