"""``python -m repro.tune`` — calibrate an LM workload, solve, save.

The standalone tune flow over the registered LM configs::

    PYTHONPATH=src python -m repro.tune --arch tiny --batches 2 \\
        --plan runs/plans/tiny.json

calibrates the chosen target program (``--target step``: one full
train step, forward + backward + AdamW, the sites ``launch/train.py``
offloads; ``--target loss``: the forward loss only — its site set is
mesh-portable, so plans calibrated under ``--mesh dp=N`` and on a
single device are byte-identical), solves the cost-optimal per-site
split assignment for the error budget, and writes the plan JSON.

Consume the plan with ``launch/train.py --plan`` (training) and
``examples/serve_lm.py --plan`` (serving); ``launch/train.py --tune N
--plan path`` runs this same calibrate-and-solve flow inline on the
exact training setup.
"""

from __future__ import annotations

import argparse
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import PrecisionPolicy, get_backend
from repro.models import Model
from repro.obs import get_logger
from repro.shard import train_mesh_setup
from repro.train import AdamW, SyntheticText

from .calibrate import Calibrator
from .plan import write_tiles_table
from .solve import count_int8_gemms, solve_plan, unpinned_family

__all__ = ["main", "tune_policy", "report_plan", "log_report"]

log = get_logger("tune")


def tune_policy(backend_spec: str, min_dim: int) -> PrecisionPolicy:
    """The calibration policy for a requested backend spec.

    The family is unpinned (the plan owns per-site splits); a pinned
    spec's count (``fp64_int8_6``) becomes the probe/default split
    count, so ``--backend fp64_int8_4`` means "probe at s=4".
    """
    pinned = getattr(get_backend(backend_spec), "pinned_splits", None)
    return PrecisionPolicy(
        backend=unpinned_family(backend_spec), min_dim=min_dim,
        **({"default_splits": pinned} if pinned else {}))


def report_plan(plan, sites) -> str:
    """Human-readable tuned-vs-uniform cost summary.

    ``sites`` is the calibration pass's (cached) site-decision list —
    offloaded under the uniform probe policy — so both costs come
    from one trace: the recorded splits give the uniform count, the
    plan's assignment (demotions contribute nothing) gives the tuned
    count.
    """
    policy = PrecisionPolicy.from_plan(plan,
                                       on_unmatched_site="ignore")

    def tuned_splits(site):
        if policy.backend_for(site.name) == "dgemm":
            return None
        return policy.splits_for(site.name)

    n_tuned = count_int8_gemms(sites, splits_for=tuned_splits)
    n_uniform = count_int8_gemms(sites)
    lines = [plan.describe(),
             f"INT8 GEMMs per step: tuned={n_tuned} vs "
             f"uniform={n_uniform} "
             f"(saved {n_uniform - n_tuned})"]
    if not plan.sites:
        lines.append("WARNING: no eligible GEMM sites — every "
                     "dot_general fell under the size/dtype gate "
                     "(per-shard shapes vs min_dim?); the plan tunes "
                     "nothing")
    if not plan.budget_met:
        lines.append("WARNING: budget unreachable even at the "
                     "split ceiling; plan uses max splits")
    return "\n".join(lines)


def log_report(logger, report: str) -> None:
    """Render a :func:`report_plan` string line-by-line through a
    :class:`repro.obs.log.Logger` (WARNING lines at warning level, so
    the rendered text matches the pre-obs ad-hoc prints exactly)."""
    for line in report.splitlines():
        if line.startswith("WARNING: "):
            logger.warning(line[len("WARNING: "):])
        else:
            logger.info(line)


def _parse(argv):
    ap = argparse.ArgumentParser(prog="python -m repro.tune",
                                 description=__doc__)
    ap.add_argument("--arch", default="tiny",
                    help="registered LMConfig preset name")
    ap.add_argument("--target", choices=("step", "loss"),
                    default="step",
                    help="program to calibrate: the full train step "
                         "or the forward loss (mesh-portable plans)")
    ap.add_argument("--batches", type=int, default=1,
                    help="calibration passes (distinct data batches)")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="fp64_int8",
                    help="backend family; a pinned count sets the "
                         "probe splits")
    ap.add_argument("--min-dim", type=int, default=128)
    ap.add_argument("--budget", type=float, default=0.0,
                    help="end-to-end relative error budget; 0 = "
                         "derive from the model dtype")
    ap.add_argument("--mesh", default="",
                    help="calibrate data-parallel over this mesh "
                         "(e.g. 'dp=8'); stats are pmax-shared so the "
                         "plan matches the single-device one")
    ap.add_argument("--plan", required=True,
                    help="output path for the plan JSON")
    return ap.parse_args(argv)


def main(argv: Optional[Sequence[str]] = None) -> List[str]:
    args = _parse(argv)
    cfg = get_config(args.arch)
    model = Model(cfg)
    opt = AdamW(lr=args.lr)
    data = SyntheticText(cfg.vocab_size, args.seq_len,
                         args.global_batch, seed=args.seed)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)

    mesh = batch_sharding = None
    if args.mesh:
        # Same 2-D bring-up as the train CLI, so a step plan is
        # calibrated against exactly the per-shard extents (and tp
        # psums) the training run will trace.
        mesh, batch_sharding, (params, opt_state), _ = \
            train_mesh_setup(args.mesh, args.global_batch, cfg,
                             (params, opt_state))

    if args.target == "step":
        from repro.launch.train import (build_sharded_train_step,
                                        build_train_step)

        fn = (build_sharded_train_step(model, opt, mesh)
              if mesh is not None else build_train_step(model, opt))

        def call_args(batch):
            return (params, opt_state, batch)
    else:
        if mesh is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            axis = mesh.axis_names[0]

            def fn(p, batch):
                def per_shard(p_s, b_s):
                    return jax.lax.pmean(model.loss(p_s, b_s), axis)

                return shard_map(per_shard, mesh=mesh,
                                 in_specs=(P(), P(axis)),
                                 out_specs=P())(p, batch)
        else:
            fn = model.loss

        def call_args(batch):
            return (params, batch)

    policy = tune_policy(args.backend, args.min_dim)
    cal = Calibrator(fn, policy)
    for i in range(max(args.batches, 1)):
        batch = jnp.asarray(data.batch(i))
        if batch_sharding is not None:
            batch = jax.device_put(batch, batch_sharding)
        cal.run(*call_args(batch))
    result = cal.result()
    plan = solve_plan(result, budget=args.budget or None)
    path = plan.save(args.plan)
    tiles_path = write_tiles_table(plan, path)
    report = report_plan(plan, cal.sites)
    log_report(log, report)
    log.info(f"plan written to {path} "
             f"(tile decisions: {tiles_path})")
    return report.splitlines()
