"""Training entry point: the LM step loop, optionally fully emulated.

``main(argv)`` trains the configured LM on the deterministic synthetic
stream up to ``--steps`` *global* steps, checkpointing as it goes and
resuming from the newest checkpoint in ``--ckpt-dir`` — kill it and
re-invoke with the same arguments and it continues bit-exactly.

``--backend`` is where this loop meets the paper: the *entire* jitted
train step (loss forward, backward, AdamW update) is wrapped in the
automatic offload transform (:func:`repro.core.intercept.offload`)
with a :class:`~repro.core.precision.PrecisionPolicy` pointing at that
registry spec — so ``--backend fp64_int8_4`` runs every projection,
MLP, and LM-head GEMM of the forward *and* backward pass through the
Ozaki INT8 emulation, while sub-``--min-dim`` contractions (notably
attention, k = head_dim) stay native, exactly like the paper's size
cutoff.  The discovered sites are printed once per run.

``--mesh dp=N`` runs the same step data-parallel over N devices
(:func:`build_sharded_train_step`): parameters replicated, batch split
over the ``dp`` axis, gradients mean-reduced with a *bucketed* psum
(grouped by byte size, issued as buckets complete so XLA overlaps
them with the remaining backward GEMMs; ``--grad-reduce`` selects the
blocking reference or a ``ppermute`` ring instead).  ``--mesh
dp=N,tp=M`` adds Megatron-style tensor parallelism: attention heads
and the SwiGLU hidden dim split over ``tp`` per the axis rules in
:mod:`repro.shard.rules`, each sublayer closed by a ``psum`` on the
``tp`` axis inside the shard_map body, and checkpoints written as
per-shard npz files plus a layout manifest.  Both compose with
``--backend``, whose offload transform descends into the ``shard_map``
body (sites named ``shmap0/...``), so every shard runs the per-shard
Ozaki split schedule its local extents call for.  On CPU, export
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first.

Precision plans (:mod:`repro.tune`) close the loop:

* ``--tune N --plan path`` calibrates the exact train step this loop
  would run (N batches, starting from the resume state), solves the
  cost-optimal per-site split assignment, writes the plan JSON, and
  exits — no training happens;
* ``--plan path`` (without ``--tune``) trains under the plan: the
  step is wrapped in ``offload(step, plan=...)``, the traced site set
  is validated against the plan fingerprint (a drifted program
  raises), and every checkpoint records the fingerprint so a later
  resume under a different precision configuration errors instead of
  silently continuing at different numerics —
  ``--allow-plan-change`` turns that error into a loud warning, the
  explicit path for adopting a freshly tuned plan on an existing
  lineage.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import PrecisionPolicy, get_backend, offload
from repro.models import Model
from repro.obs import MetricsRun, NumericsMonitor, get_logger
from repro.shard import (DEFAULT_BUCKET_BYTES, bucket_stats,
                         reduce_gradients, train_mesh_setup)
from repro.train import AdamW, SyntheticText, checkpoint
from repro.tune.solve import count_int8_gemms

__all__ = ["main", "build_train_step", "build_sharded_train_step"]

log = get_logger("train")
offload_log = get_logger("offload")


def build_train_step(model: Model, opt: AdamW):
    """The pure ``(params, opt_state, batch) -> (params, opt_state,
    loss)`` step.  Kept separate so tests and benchmarks can wrap the
    exact function the trainer runs."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state = opt.update(grads, params, opt_state)
        return params, opt_state, loss

    return train_step


def build_sharded_train_step(model: Model, opt: AdamW, mesh,
                             axis: str | None = None, *,
                             grad_reduce: str = "bucketed",
                             bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """dp(×tp)-parallel version of :func:`build_train_step` over ``mesh``.

    Each data-parallel shard runs value_and_grad on its batch slice;
    losses are ``pmean``-ed and gradients mean-reduced across the dp
    axis with :func:`repro.shard.reduce_gradients` — bucketed by byte
    size so XLA can overlap early buckets with the remaining backward
    GEMMs (``grad_reduce="bucketed"``, bit-identical to the per-leaf
    ``pmean`` it replaced; ``"blocking"`` and ``"ppermute"`` are the
    reference and the ring-pipelined alternative).  Every shard then
    applies the identical AdamW update, so the global step equals the
    single-device step on the full batch, which the dp=N equivalence
    tests pin to 1e-10.

    When ``mesh`` carries a ``tp`` axis of size > 1, the step runs
    Megatron-style tensor parallelism on top: parameters enter the
    body per the LM axis rules (attention heads and the SwiGLU hidden
    dim column/row-sharded on ``tp``, the rest replicated), the model
    is rebuilt with ``tp_axis="tp"`` so each sublayer closes with a
    ``psum`` over ``tp`` inside the shard_map body, and the AdamW
    update runs elementwise on the local parameter blocks.

    Wrapping the returned function in ``offload(...)`` routes the
    per-shard forward AND backward GEMMs through the registry backend
    (sites named ``shmap0/...``) — the per-shard contraction extents
    (``q_dim/tp``, ``d_ff/tp``, per-shard batch rows for ``dW``)
    drive the size gate and plan lookup, exactly as a single device
    of that shard size would.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.shard import (TP_AXIS, train_state_specs, validate_tp)

    dp = axis or mesh.axis_names[0]
    if dp == TP_AXIS and len(mesh.axis_names) > 1:
        dp = next(a for a in mesh.axis_names if a != TP_AXIS)
    tp = dict(mesh.shape).get(TP_AXIS, 1)
    dp_size = dict(mesh.shape)[dp]

    if tp > 1:
        validate_tp(model.cfg, tp)
        model = Model(model.cfg, tp_axis=TP_AXIS)
        param_specs, opt_specs = train_state_specs(model.cfg)
    else:
        param_specs, opt_specs = P(), P()

    def per_shard_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        loss = jax.lax.pmean(loss, dp)
        grads = reduce_gradients(grads, dp, dp_size,
                                 mode=grad_reduce,
                                 bucket_bytes=bucket_bytes)
        params, opt_state = opt.update(grads, params, opt_state)
        return params, opt_state, loss

    # check_rep=False: the tp model's custom_vjp collective wrappers
    # have no replication-tracking rules, and all cross-shard sums
    # here are explicit psums anyway.
    return shard_map(per_shard_step, mesh=mesh,
                     in_specs=(param_specs, opt_specs, P(dp)),
                     out_specs=(param_specs, opt_specs, P()),
                     check_rep=False)


def _describe_sites(sites) -> None:
    on = [s for s in sites if s.offloaded]
    off = [s for s in sites if not s.offloaded]
    offload_log.info(f"{len(on)} of {len(sites)} dot_general sites "
                     "routed through the registry backend:")
    for s in on:
        offload_log.info(f"  {s}")
    if off:
        offload_log.info(f"{len(off)} sites stay native "
                         "(size/dtype gate), e.g. "
                         + "; ".join(repr(s) for s in off[:3]))


def _parse(argv):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--overrides", default="",
                    help="JSON dict of LMConfig overrides")
    ap.add_argument("--steps", type=int, default=300,
                    help="train until this GLOBAL step (resume-aware)")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="",
                    help="GEMM registry spec (e.g. fp64_int8_4); empty "
                         "= native XLA matmuls")
    ap.add_argument("--plan", default="",
                    help="precision-plan JSON: with --tune, where the "
                         "calibrated plan is written; without, the "
                         "plan the train step runs under")
    ap.add_argument("--tune", type=int, default=0,
                    help="calibrate the train step over this many "
                         "batches, solve, write --plan, and exit "
                         "(no training)")
    ap.add_argument("--budget", type=float, default=0.0,
                    help="end-to-end relative error budget for "
                         "--tune; 0 = derive from the model dtype")
    ap.add_argument("--allow-plan-change", action="store_true",
                    help="resume a lineage under a DIFFERENT "
                         "precision configuration (loud warning "
                         "instead of an error); the intended path for "
                         "adopting a plan tuned at the resume state")
    ap.add_argument("--mesh", default="",
                    help="mesh spec: 'dp=8' (data parallel) or "
                         "'dp=4,tp=2' (2-D: tp splits attention heads "
                         "and the MLP hidden dim); empty = single "
                         "device.  On CPU export XLA_FLAGS=--xla_"
                         "force_host_platform_device_count=N first")
    ap.add_argument("--grad-reduce", default="bucketed",
                    choices=["bucketed", "blocking", "ppermute"],
                    help="gradient all-reduce strategy on the dp axis "
                         "(bucketed = overlapped with the remaining "
                         "backward, bit-identical to pmean; ppermute "
                         "= ring pipeline, replicas agree to rounding "
                         "only)")
    ap.add_argument("--bucket-mb", type=float, default=0.0,
                    help="gradient bucket size in MiB for "
                         "--grad-reduce bucketed; 0 = default (4)")
    ap.add_argument("--min-dim", type=int, default=128,
                    help="offload size gate: min(m,k,n) for emulation")
    ap.add_argument("--ckpt-dir", default="",
                    help="default: runs/ckpt/<arch>")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-dir", default="",
                    help="telemetry directory (repro.obs JSONL runs); "
                         "default: <ckpt-dir>/metrics; 'none' disables")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve the run's registry live at "
                         "http://127.0.0.1:PORT/metrics (Prometheus "
                         "text format; 0 = ephemeral port) while "
                         "training; requires telemetry on")
    ap.add_argument("--metrics-push-url", default="",
                    help="push this process's registry snapshot to an "
                         "aggregating metrics server (http://host:port"
                         "/push) every --log-every steps — how a "
                         "multi-process mesh job publishes into one "
                         "scrapeable /metrics endpoint")
    ap.add_argument("--numerics-every", type=int, default=25,
                    help="NumericsMonitor period: every Nth step "
                         "re-measure the probe site's realized error "
                         "against dgemm (emulated runs with telemetry "
                         "on); 0 disables")
    return ap.parse_args(argv)


def _run_tune(args, train_step, params, opt_state, data, start,
              batch_sharding) -> None:
    """``--tune N --plan path``: calibrate, solve, save, report."""
    from repro.tune import Calibrator, solve_plan
    from repro.tune.cli import log_report, report_plan, tune_policy
    from repro.tune.plan import write_tiles_table

    policy = tune_policy(args.backend or "fp64_int8", args.min_dim)
    log.info(f"tuning: {args.tune} calibration batch(es) from "
             f"step {start}, probe s={policy.default_splits}, "
             f"backend family {policy.backend}")
    cal = Calibrator(train_step, policy)
    for i in range(args.tune):
        batch = jnp.asarray(data.batch(start + i))
        if batch_sharding is not None:
            batch = jax.device_put(batch, batch_sharding)
        cal.run(params, opt_state, batch)
    plan = solve_plan(cal.result(), budget=args.budget or None)
    path = plan.save(args.plan)
    tiles_path = write_tiles_table(plan, path)
    log_report(get_logger("tune"), report_plan(plan, cal.sites))
    log.info(f"plan written to {path} (tile decisions: "
             f"{tiles_path}); train with --plan {path}")


def _check_resume_plan(ckpt_dir, start: int, plan,
                       allow_change: bool) -> None:
    """Refuse to resume across a precision-configuration change.

    The checkpoint metadata carries the plan fingerprint the run was
    training under; resuming with a different plan (or none, or from
    a pre-plan checkpoint with a plan now active) would silently
    continue the loss curve at different numerics — error unless the
    change is explicit (``--allow-plan-change``, the intended way to
    adopt a freshly tuned plan on an existing lineage: train
    plan-less, ``--tune`` at the resume state, resume with ``--plan
    ... --allow-plan-change`` once).
    """
    ckpt_fp = checkpoint.load_meta(ckpt_dir, start).get(
        "plan_fingerprint")
    active_fp = plan.fingerprint if plan is not None else None
    if ckpt_fp == active_fp:
        return
    if allow_change:
        log.warning(f"precision configuration changes at "
                    f"step {start}: {ckpt_fp or '<none>'} -> "
                    f"{active_fp or '<none>'} (--allow-plan-change); "
                    "later checkpoints record the new fingerprint")
        return
    raise SystemExit(
        f"[train] checkpoint step {start} in {ckpt_dir} was written "
        f"under precision plan {ckpt_fp or '<none>'} but this run is "
        f"configured with {active_fp or '<none>'}: resuming would "
        "silently change training numerics mid-lineage. Pass the "
        "matching --plan; or, to adopt this configuration on purpose "
        "(e.g. a plan just tuned at this resume state), re-run with "
        "--allow-plan-change.")


def main(argv: Optional[Sequence[str]] = None) -> List[float]:
    """Run the loop; returns the per-step losses of THIS invocation."""
    args = _parse(argv)
    if args.tune and not args.plan:
        raise SystemExit("[train] --tune needs --plan (where to write "
                         "the calibrated plan)")
    if args.plan and args.backend and not args.tune:
        raise SystemExit("[train] --plan and --backend are both "
                         "precision configurations; pass one (with "
                         "--tune, --backend sets the probe family)")
    cfg = get_config(args.arch)
    if args.overrides:
        cfg = cfg.replace(**json.loads(args.overrides))
    model = Model(cfg)
    opt = AdamW(lr=args.lr)
    data = SyntheticText(cfg.vocab_size, args.seq_len,
                         args.global_batch, seed=args.seed)
    ckpt_dir = args.ckpt_dir or f"runs/ckpt/{args.arch}"

    params = model.init_params(jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    start = checkpoint.latest_step(ckpt_dir) or 0
    if start:
        log.info(f"resuming from step {start} in {ckpt_dir}")
        params, opt_state = checkpoint.restore(ckpt_dir, start,
                                               (params, opt_state))
    if start >= args.steps and not args.tune:
        log.info(f"checkpoint step {start} >= --steps "
                 f"{args.steps}; nothing to do")
        return []

    mesh = batch_sharding = state_specs = None
    bucket_bytes = (int(args.bucket_mb * (1 << 20)) if args.bucket_mb
                    else DEFAULT_BUCKET_BYTES)
    if args.mesh:
        mesh, batch_sharding, (params, opt_state), state_specs = \
            train_mesh_setup(args.mesh, args.global_batch, cfg,
                             (params, opt_state))
        shape = dict(mesh.shape)
        log.info(f"mesh {args.mesh}: {mesh.size} devices "
                 f"(dp={shape.get('dp', 1)} tp={shape.get('tp', 1)}), "
                 f"per-shard batch "
                 f"{args.global_batch // shape.get('dp', 1)}, "
                 f"grad-reduce {args.grad_reduce}")
        if args.grad_reduce == "bucketed":
            n_buckets, per_psum = bucket_stats(params, bucket_bytes)
            log.info(f"gradient buckets: {n_buckets} psum(s), "
                     f"{[round(b / 1024) for b in per_psum]} KiB")
        train_step = build_sharded_train_step(
            model, opt, mesh, grad_reduce=args.grad_reduce,
            bucket_bytes=bucket_bytes)
    else:
        train_step = build_train_step(model, opt)

    if args.tune:
        _run_tune(args, train_step, params, opt_state, data, start,
                  batch_sharding)
        return []

    plan = None
    if args.plan:
        from repro.tune import PrecisionPlan

        plan = PrecisionPlan.load(args.plan)
    if start:
        _check_resume_plan(ckpt_dir, start, plan,
                           args.allow_plan_change)
    ckpt_meta = {
        "plan_fingerprint": plan.fingerprint if plan is not None
        else None,
        # Informational (resume enforcement keys on the fingerprint).
        "backend": args.backend or None,
        "plan_path": args.plan or None,
    }
    # A tp mesh writes the per-shard layout (one npz per tp shard +
    # manifest); restore reassembles the global tree, so a later
    # resume may use any mesh shape — or none.
    tp_sharded = (state_specs is not None and mesh is not None
                  and dict(mesh.shape).get("tp", 1) > 1)

    def save_ckpt(step_no, state):
        if tp_sharded:
            checkpoint.save_sharded(ckpt_dir, step_no, state,
                                    state_specs, mesh, meta=ckpt_meta)
        else:
            checkpoint.save(ckpt_dir, step_no, state, meta=ckpt_meta)

    # Telemetry (repro.obs): one MetricsRun per invocation, scoped to
    # the checkpoint lineage by default so test/tmp runs stay in tmp.
    metrics = None
    if args.metrics_dir != "none":
        metrics = MetricsRun(args.metrics_dir
                             or f"{ckpt_dir}/metrics")
        metrics.event("config", arch=args.arch, steps=args.steps,
                      start=start, seq_len=args.seq_len,
                      global_batch=args.global_batch,
                      backend=args.backend or None,
                      plan=args.plan or None, mesh=args.mesh or None)

    # Live observability: a pull endpoint over this run's registry
    # and/or periodic pushes into another process's aggregator.
    mserver = None
    push_url = args.metrics_push_url if metrics is not None else ""
    push_source = f"train-proc{jax.process_index()}"
    if metrics is not None and args.metrics_port is not None:
        from repro.obs import MetricsServer

        mserver = MetricsServer(metrics.registry,
                                port=args.metrics_port,
                                runs_dir=metrics.directory).start()
        log.info(f"live metrics: {mserver.url}/metrics")

    def push_metrics() -> None:
        if not push_url:
            return
        from repro.obs import push_snapshot

        try:
            push_snapshot(push_url, push_source, metrics.registry)
        except OSError as e:
            log.warning(f"metrics push to {push_url} failed: {e}")

    on_site_event = metrics.site_event_handler() if metrics else None
    monitor = None
    policy = None
    if plan is not None:
        policy = PrecisionPolicy.from_plan(plan)
        wrapped = offload(train_step, policy, plan=plan,
                          plan_match="strict",
                          on_site_event=on_site_event)
        log.info(f"precision plan {args.plan} "
                 f"({plan.fingerprint}, backend={plan.backend}, "
                 f"{len(plan.sites)} sites"
                 + (f", {len(plan.demoted_sites())} demoted" if
                    plan.demoted_sites() else "") + ")")
    elif args.backend:
        # A pinned spec ("fp64_int8_4") is authoritative at execution;
        # mirror it into the policy so the printed site report shows
        # the split count that actually runs.
        pinned = getattr(get_backend(args.backend), "pinned_splits",
                         None)
        policy = PrecisionPolicy(backend=args.backend,
                                 min_dim=args.min_dim,
                                 **({"default_splits": pinned}
                                    if pinned else {}))
        wrapped = offload(train_step, policy,
                          on_site_event=on_site_event)
        log.info(f"backend={args.backend} min_dim={args.min_dim} "
                 f"({cfg.num_params()/1e6:.1f}M params)")
    if policy is not None:
        sites = wrapped.sites(params, opt_state, data.batch(start))
        _describe_sites(sites)
        step_fn = jax.jit(wrapped)
        int8_per_step = count_int8_gemms(sites)
        if metrics is not None:
            metrics.declare_sites(sites)
            if args.numerics_every > 0:
                monitor = NumericsMonitor(
                    train_step, plan=plan,
                    policy=None if plan is not None else policy,
                    every=args.numerics_every,
                    registry=metrics.registry, sink=metrics.sink,
                    log=log)
    else:
        step_fn = jax.jit(train_step)
        int8_per_step = 0

    losses: List[float] = []
    t_last = time.perf_counter()
    try:
        for step in range(start, args.steps):
            batch = jnp.asarray(data.batch(step))
            if batch_sharding is not None:
                batch = jax.device_put(batch, batch_sharding)
            if monitor is not None:
                monitor.maybe_check(step, params, opt_state, batch)
            t_step = time.perf_counter()
            if metrics is not None:
                with metrics.tracer.span("train_step", step=step + 1):
                    params, opt_state, loss = step_fn(params,
                                                      opt_state, batch)
                    # Blocking inside the span so it measures the whole
                    # device step, not just the dispatch.
                    losses.append(float(loss))
            else:
                params, opt_state, loss = step_fn(params, opt_state,
                                                  batch)
                losses.append(float(loss))
            step_ms = (time.perf_counter() - t_step) * 1e3
            if metrics is not None:
                metrics.event("step", step=step + 1, loss=losses[-1],
                              ms=step_ms, int8_gemms=int8_per_step)
            if step == start or (step + 1) % args.log_every == 0 \
                    or step + 1 == args.steps:
                now = time.perf_counter()
                log.info(f"step {step + 1}/{args.steps} "
                         f"loss={losses[-1]:.4f} "
                         f"({(now - t_last) * 1e3:.0f} ms)")
                t_last = now
                push_metrics()
            if (step + 1) % args.ckpt_every == 0:
                save_ckpt(step + 1, (params, opt_state))
        save_ckpt(args.steps, (params, opt_state))
    finally:
        if metrics is not None:
            # Drain async site-event callbacks before the final
            # registry snapshot, so execution counts are complete.
            jax.effects_barrier()
            push_metrics()
            metrics.close()
        if mserver is not None:
            mserver.close()
    log.info(f"done at step {args.steps}; checkpoint in {ckpt_dir}")
    if metrics is not None:
        log.info(f"telemetry: {metrics.sink.path} (inspect with "
                 f"python -m repro.obs report {metrics.directory})")
    return losses


if __name__ == "__main__":
    main()
