"""Training entry point: the LM step loop, optionally fully emulated.

``main(argv)`` trains the configured LM on the deterministic synthetic
stream up to ``--steps`` *global* steps, checkpointing as it goes and
resuming from the newest checkpoint in ``--ckpt-dir`` — kill it and
re-invoke with the same arguments and it continues bit-exactly.

``--backend`` is where this loop meets the paper: the *entire* jitted
train step (loss forward, backward, AdamW update) is wrapped in the
automatic offload transform (:func:`repro.core.intercept.offload`)
with a :class:`~repro.core.precision.PrecisionPolicy` pointing at that
registry spec — so ``--backend fp64_int8_4`` runs every projection,
MLP, and LM-head GEMM of the forward *and* backward pass through the
Ozaki INT8 emulation, while sub-``--min-dim`` contractions (notably
attention, k = head_dim) stay native, exactly like the paper's size
cutoff.  The discovered sites are printed once per run.

``--mesh dp=N`` runs the same step data-parallel over N devices
(:func:`build_sharded_train_step`): parameters replicated, batch split
over the ``dp`` axis, gradients ``pmean``-ed — and it composes with
``--backend``, whose offload transform descends into the ``shard_map``
body (sites named ``shmap0/...``), so every shard runs the identical
per-shard Ozaki split schedule.  On CPU, export
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first.

Precision plans (:mod:`repro.tune`) close the loop:

* ``--tune N --plan path`` calibrates the exact train step this loop
  would run (N batches, starting from the resume state), solves the
  cost-optimal per-site split assignment, writes the plan JSON, and
  exits — no training happens;
* ``--plan path`` (without ``--tune``) trains under the plan: the
  step is wrapped in ``offload(step, plan=...)``, the traced site set
  is validated against the plan fingerprint (a drifted program
  raises), and every checkpoint records the fingerprint so a later
  resume under a different precision configuration errors instead of
  silently continuing at different numerics —
  ``--allow-plan-change`` turns that error into a loud warning, the
  explicit path for adopting a freshly tuned plan on an existing
  lineage.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import PrecisionPolicy, get_backend, offload
from repro.models import Model
from repro.obs import MetricsRun, NumericsMonitor, get_logger
from repro.shard import data_parallel_setup
from repro.train import AdamW, SyntheticText, checkpoint
from repro.tune.solve import count_int8_gemms

__all__ = ["main", "build_train_step", "build_sharded_train_step"]

log = get_logger("train")
offload_log = get_logger("offload")


def build_train_step(model: Model, opt: AdamW):
    """The pure ``(params, opt_state, batch) -> (params, opt_state,
    loss)`` step.  Kept separate so tests and benchmarks can wrap the
    exact function the trainer runs."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state = opt.update(grads, params, opt_state)
        return params, opt_state, loss

    return train_step


def build_sharded_train_step(model: Model, opt: AdamW, mesh,
                             axis: str | None = None):
    """Data-parallel version of :func:`build_train_step` over ``mesh``.

    Each shard runs value_and_grad on its batch slice, losses and
    gradients are ``pmean``-ed across ``axis``, and every shard applies
    the identical AdamW update to its replicated parameters — so the
    global step equals the single-device step on the full batch (equal
    shard sizes make mean-of-shard-means the global mean), which the
    dp=N equivalence tests pin down to 1e-10.

    Wrapping the returned function in ``offload(...)`` routes the
    per-shard forward AND backward GEMMs through the registry backend
    (sites named ``shmap0/...``), with the same per-shard split
    schedule a single-device run would use.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = axis or mesh.axis_names[0]

    def per_shard_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        loss = jax.lax.pmean(loss, axis)
        grads = jax.tree_util.tree_map(
            lambda g: jax.lax.pmean(g, axis), grads)
        params, opt_state = opt.update(grads, params, opt_state)
        return params, opt_state, loss

    return shard_map(per_shard_step, mesh=mesh,
                     in_specs=(P(), P(), P(axis)),
                     out_specs=(P(), P(), P()))


def _describe_sites(sites) -> None:
    on = [s for s in sites if s.offloaded]
    off = [s for s in sites if not s.offloaded]
    offload_log.info(f"{len(on)} of {len(sites)} dot_general sites "
                     "routed through the registry backend:")
    for s in on:
        offload_log.info(f"  {s}")
    if off:
        offload_log.info(f"{len(off)} sites stay native "
                         "(size/dtype gate), e.g. "
                         + "; ".join(repr(s) for s in off[:3]))


def _parse(argv):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--overrides", default="",
                    help="JSON dict of LMConfig overrides")
    ap.add_argument("--steps", type=int, default=300,
                    help="train until this GLOBAL step (resume-aware)")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="",
                    help="GEMM registry spec (e.g. fp64_int8_4); empty "
                         "= native XLA matmuls")
    ap.add_argument("--plan", default="",
                    help="precision-plan JSON: with --tune, where the "
                         "calibrated plan is written; without, the "
                         "plan the train step runs under")
    ap.add_argument("--tune", type=int, default=0,
                    help="calibrate the train step over this many "
                         "batches, solve, write --plan, and exit "
                         "(no training)")
    ap.add_argument("--budget", type=float, default=0.0,
                    help="end-to-end relative error budget for "
                         "--tune; 0 = derive from the model dtype")
    ap.add_argument("--allow-plan-change", action="store_true",
                    help="resume a lineage under a DIFFERENT "
                         "precision configuration (loud warning "
                         "instead of an error); the intended path for "
                         "adopting a plan tuned at the resume state")
    ap.add_argument("--mesh", default="",
                    help="mesh spec for data-parallel training (e.g. "
                         "'dp=8'); empty = single device.  On CPU "
                         "export XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N first")
    ap.add_argument("--min-dim", type=int, default=128,
                    help="offload size gate: min(m,k,n) for emulation")
    ap.add_argument("--ckpt-dir", default="",
                    help="default: runs/ckpt/<arch>")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-dir", default="",
                    help="telemetry directory (repro.obs JSONL runs); "
                         "default: <ckpt-dir>/metrics; 'none' disables")
    ap.add_argument("--numerics-every", type=int, default=25,
                    help="NumericsMonitor period: every Nth step "
                         "re-measure the probe site's realized error "
                         "against dgemm (emulated runs with telemetry "
                         "on); 0 disables")
    return ap.parse_args(argv)


def _run_tune(args, train_step, params, opt_state, data, start,
              batch_sharding) -> None:
    """``--tune N --plan path``: calibrate, solve, save, report."""
    from repro.tune import Calibrator, solve_plan
    from repro.tune.cli import log_report, report_plan, tune_policy
    from repro.tune.plan import write_tiles_table

    policy = tune_policy(args.backend or "fp64_int8", args.min_dim)
    log.info(f"tuning: {args.tune} calibration batch(es) from "
             f"step {start}, probe s={policy.default_splits}, "
             f"backend family {policy.backend}")
    cal = Calibrator(train_step, policy)
    for i in range(args.tune):
        batch = jnp.asarray(data.batch(start + i))
        if batch_sharding is not None:
            batch = jax.device_put(batch, batch_sharding)
        cal.run(params, opt_state, batch)
    plan = solve_plan(cal.result(), budget=args.budget or None)
    path = plan.save(args.plan)
    tiles_path = write_tiles_table(plan, path)
    log_report(get_logger("tune"), report_plan(plan, cal.sites))
    log.info(f"plan written to {path} (tile decisions: "
             f"{tiles_path}); train with --plan {path}")


def _check_resume_plan(ckpt_dir, start: int, plan,
                       allow_change: bool) -> None:
    """Refuse to resume across a precision-configuration change.

    The checkpoint metadata carries the plan fingerprint the run was
    training under; resuming with a different plan (or none, or from
    a pre-plan checkpoint with a plan now active) would silently
    continue the loss curve at different numerics — error unless the
    change is explicit (``--allow-plan-change``, the intended way to
    adopt a freshly tuned plan on an existing lineage: train
    plan-less, ``--tune`` at the resume state, resume with ``--plan
    ... --allow-plan-change`` once).
    """
    ckpt_fp = checkpoint.load_meta(ckpt_dir, start).get(
        "plan_fingerprint")
    active_fp = plan.fingerprint if plan is not None else None
    if ckpt_fp == active_fp:
        return
    if allow_change:
        log.warning(f"precision configuration changes at "
                    f"step {start}: {ckpt_fp or '<none>'} -> "
                    f"{active_fp or '<none>'} (--allow-plan-change); "
                    "later checkpoints record the new fingerprint")
        return
    raise SystemExit(
        f"[train] checkpoint step {start} in {ckpt_dir} was written "
        f"under precision plan {ckpt_fp or '<none>'} but this run is "
        f"configured with {active_fp or '<none>'}: resuming would "
        "silently change training numerics mid-lineage. Pass the "
        "matching --plan; or, to adopt this configuration on purpose "
        "(e.g. a plan just tuned at this resume state), re-run with "
        "--allow-plan-change.")


def main(argv: Optional[Sequence[str]] = None) -> List[float]:
    """Run the loop; returns the per-step losses of THIS invocation."""
    args = _parse(argv)
    if args.tune and not args.plan:
        raise SystemExit("[train] --tune needs --plan (where to write "
                         "the calibrated plan)")
    if args.plan and args.backend and not args.tune:
        raise SystemExit("[train] --plan and --backend are both "
                         "precision configurations; pass one (with "
                         "--tune, --backend sets the probe family)")
    cfg = get_config(args.arch)
    if args.overrides:
        cfg = cfg.replace(**json.loads(args.overrides))
    model = Model(cfg)
    opt = AdamW(lr=args.lr)
    data = SyntheticText(cfg.vocab_size, args.seq_len,
                         args.global_batch, seed=args.seed)
    ckpt_dir = args.ckpt_dir or f"runs/ckpt/{args.arch}"

    params = model.init_params(jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    start = checkpoint.latest_step(ckpt_dir) or 0
    if start:
        log.info(f"resuming from step {start} in {ckpt_dir}")
        params, opt_state = checkpoint.restore(ckpt_dir, start,
                                               (params, opt_state))
    if start >= args.steps and not args.tune:
        log.info(f"checkpoint step {start} >= --steps "
                 f"{args.steps}; nothing to do")
        return []

    mesh = batch_sharding = None
    if args.mesh:
        mesh, batch_sharding, (params, opt_state) = \
            data_parallel_setup(args.mesh, args.global_batch,
                                (params, opt_state))
        log.info(f"mesh {args.mesh}: {mesh.size} devices, "
                 f"per-shard batch {args.global_batch // mesh.size}")
        train_step = build_sharded_train_step(model, opt, mesh)
    else:
        train_step = build_train_step(model, opt)

    if args.tune:
        _run_tune(args, train_step, params, opt_state, data, start,
                  batch_sharding)
        return []

    plan = None
    if args.plan:
        from repro.tune import PrecisionPlan

        plan = PrecisionPlan.load(args.plan)
    if start:
        _check_resume_plan(ckpt_dir, start, plan,
                           args.allow_plan_change)
    ckpt_meta = {
        "plan_fingerprint": plan.fingerprint if plan is not None
        else None,
        # Informational (resume enforcement keys on the fingerprint).
        "backend": args.backend or None,
        "plan_path": args.plan or None,
    }

    # Telemetry (repro.obs): one MetricsRun per invocation, scoped to
    # the checkpoint lineage by default so test/tmp runs stay in tmp.
    metrics = None
    if args.metrics_dir != "none":
        metrics = MetricsRun(args.metrics_dir
                             or f"{ckpt_dir}/metrics")
        metrics.event("config", arch=args.arch, steps=args.steps,
                      start=start, seq_len=args.seq_len,
                      global_batch=args.global_batch,
                      backend=args.backend or None,
                      plan=args.plan or None, mesh=args.mesh or None)

    on_site_event = metrics.site_event_handler() if metrics else None
    monitor = None
    policy = None
    if plan is not None:
        policy = PrecisionPolicy.from_plan(plan)
        wrapped = offload(train_step, policy, plan=plan,
                          plan_match="strict",
                          on_site_event=on_site_event)
        log.info(f"precision plan {args.plan} "
                 f"({plan.fingerprint}, backend={plan.backend}, "
                 f"{len(plan.sites)} sites"
                 + (f", {len(plan.demoted_sites())} demoted" if
                    plan.demoted_sites() else "") + ")")
    elif args.backend:
        # A pinned spec ("fp64_int8_4") is authoritative at execution;
        # mirror it into the policy so the printed site report shows
        # the split count that actually runs.
        pinned = getattr(get_backend(args.backend), "pinned_splits",
                         None)
        policy = PrecisionPolicy(backend=args.backend,
                                 min_dim=args.min_dim,
                                 **({"default_splits": pinned}
                                    if pinned else {}))
        wrapped = offload(train_step, policy,
                          on_site_event=on_site_event)
        log.info(f"backend={args.backend} min_dim={args.min_dim} "
                 f"({cfg.num_params()/1e6:.1f}M params)")
    if policy is not None:
        sites = wrapped.sites(params, opt_state, data.batch(start))
        _describe_sites(sites)
        step_fn = jax.jit(wrapped)
        int8_per_step = count_int8_gemms(sites)
        if metrics is not None:
            metrics.declare_sites(sites)
            if args.numerics_every > 0:
                monitor = NumericsMonitor(
                    train_step, plan=plan,
                    policy=None if plan is not None else policy,
                    every=args.numerics_every,
                    registry=metrics.registry, sink=metrics.sink,
                    log=log)
    else:
        step_fn = jax.jit(train_step)
        int8_per_step = 0

    losses: List[float] = []
    t_last = time.perf_counter()
    try:
        for step in range(start, args.steps):
            batch = jnp.asarray(data.batch(step))
            if batch_sharding is not None:
                batch = jax.device_put(batch, batch_sharding)
            if monitor is not None:
                monitor.maybe_check(step, params, opt_state, batch)
            t_step = time.perf_counter()
            if metrics is not None:
                with metrics.tracer.span("train_step", step=step + 1):
                    params, opt_state, loss = step_fn(params,
                                                      opt_state, batch)
                    # Blocking inside the span so it measures the whole
                    # device step, not just the dispatch.
                    losses.append(float(loss))
            else:
                params, opt_state, loss = step_fn(params, opt_state,
                                                  batch)
                losses.append(float(loss))
            step_ms = (time.perf_counter() - t_step) * 1e3
            if metrics is not None:
                metrics.event("step", step=step + 1, loss=losses[-1],
                              ms=step_ms, int8_gemms=int8_per_step)
            if step == start or (step + 1) % args.log_every == 0 \
                    or step + 1 == args.steps:
                now = time.perf_counter()
                log.info(f"step {step + 1}/{args.steps} "
                         f"loss={losses[-1]:.4f} "
                         f"({(now - t_last) * 1e3:.0f} ms)")
                t_last = now
            if (step + 1) % args.ckpt_every == 0:
                checkpoint.save(ckpt_dir, step + 1,
                                (params, opt_state), meta=ckpt_meta)
        checkpoint.save(ckpt_dir, args.steps, (params, opt_state),
                        meta=ckpt_meta)
    finally:
        if metrics is not None:
            # Drain async site-event callbacks before the final
            # registry snapshot, so execution counts are complete.
            jax.effects_barrier()
            metrics.close()
    log.info(f"done at step {args.steps}; checkpoint in {ckpt_dir}")
    if metrics is not None:
        log.info(f"telemetry: {metrics.sink.path} (inspect with "
                 f"python -m repro.obs report {metrics.directory})")
    return losses


if __name__ == "__main__":
    main()
