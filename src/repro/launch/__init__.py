"""repro.launch — runnable entry points (training loop, etc.)."""

__all__ = ["train"]
