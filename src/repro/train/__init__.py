"""repro.train — optimizer, synthetic data, checkpointing."""

from . import checkpoint
from .checkpoint import CheckpointError
from .data import SyntheticText
from .optimizer import AdamW

__all__ = ["AdamW", "CheckpointError", "SyntheticText", "checkpoint"]
