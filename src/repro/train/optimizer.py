"""AdamW on plain pytrees (optax is not in the container).

The update is pure tree arithmetic — no matmuls — so wrapping a whole
train step in the offload transform leaves the optimizer untouched
while the loss forward *and* backward GEMMs run emulated.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["AdamW"]


@dataclasses.dataclass(frozen=True)
class AdamW:
    """Decoupled-weight-decay Adam.

    ``init(params)`` builds the state pytree; ``update(grads, params,
    state)`` returns ``(new_params, new_state)``.  Both are pure and
    jit-safe; the state is a plain dict so it checkpoints with the same
    machinery as the parameters.
    """

    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01

    @staticmethod
    def _acc_dtype(p):
        # Moments and update arithmetic run in at-least-f32: f32 for
        # f32/bf16 params (unchanged), f64 for f64 params — silently
        # quantizing an f64 model's optimizer to f32 would cap the
        # dp=N == single-device train equivalence at f32 resolution.
        return jnp.promote_types(p.dtype, jnp.float32)

    def init(self, params) -> dict:
        zeros = lambda t: jax.tree_util.tree_map(  # noqa: E731
            lambda p: jnp.zeros_like(p, dtype=self._acc_dtype(p)), t)
        return {"step": jnp.zeros((), jnp.int32),
                "mu": zeros(params), "nu": zeros(params)}

    def update(self, grads, params, state):
        t = state["step"] + 1

        def moment(old, g, beta):
            g = g.astype(old.dtype)
            return beta * old + (1.0 - beta) * g

        mu = jax.tree_util.tree_map(
            lambda m, g: moment(m, g, self.b1), state["mu"], grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: moment(v, g * g, self.b2), state["nu"], grads)

        def step(p, m, v):
            # Bias corrections in the leaf's accumulation dtype: a
            # shared f32 bc1/bc2 would cap an f64 model's update at
            # f32 resolution.
            tf = t.astype(m.dtype)
            bc1 = 1.0 - self.b1 ** tf
            bc2 = 1.0 - self.b2 ** tf
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            upd = upd + self.weight_decay * p.astype(upd.dtype)
            return (p.astype(upd.dtype) - self.lr * upd).astype(p.dtype)

        new_params = jax.tree_util.tree_map(step, params, mu, nu)
        return new_params, {"step": t, "mu": mu, "nu": nu}
