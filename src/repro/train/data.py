"""Deterministic synthetic LM data.

Batches are a pure function of ``(seed, step)`` — no files, no state —
which is what makes kill-and-resume training bit-exact: a run restored
from step ``s`` regenerates exactly the batches an uninterrupted run
would have seen from step ``s`` on.

The stream mixes two signals at different learning speeds:

* a random walk over the vocabulary — token ``t+1`` is ``(t + delta)
  mod vocab`` with ``delta`` from a small skewed set.  Near-uniform
  marginal, ~1.3 nats of conditional entropy: the "hard" part that
  real training runs chew on over hundreds of steps;
* an *anchor*: each position is replaced by token 0 with probability
  0.25 (the walk's hidden state still advances).  This skews the
  unigram marginal, which a zero-initialized LM head fits within the
  first couple of optimizer steps — so even a 4-step CI smoke run sees
  a strictly improving loss instead of noise around ``log(vocab)``.
"""

from __future__ import annotations

import numpy as np

__all__ = ["SyntheticText"]

_DELTAS = np.array([1, 2, 3, 5, 8], dtype=np.int64)
_PROBS = np.array([0.40, 0.30, 0.15, 0.10, 0.05])
_ANCHOR_P = 0.25


class SyntheticText:
    """Deterministic ``(batch, seq_len + 1)`` token batches by step index."""

    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 0):
        if vocab_size <= int(_DELTAS.max()):
            raise ValueError(f"vocab_size={vocab_size} too small for "
                             "the synthetic walk")
        self.vocab_size = int(vocab_size)
        self.seq_len = int(seq_len)
        self.batch_size = int(batch_size)
        self.seed = int(seed)

    def batch(self, step: int) -> np.ndarray:
        """Tokens for ``step`` — (batch, seq_len + 1) int32.

        Column ``0..seq_len-1`` are the inputs, ``1..seq_len`` the
        targets (the caller shifts).  Same ``(seed, step)`` -> same
        bytes, on any platform numpy supports.
        """
        rng = np.random.default_rng([self.seed, int(step)])
        B, T, V = self.batch_size, self.seq_len, self.vocab_size
        start = rng.integers(0, V, size=(B, 1))
        deltas = rng.choice(_DELTAS, size=(B, T), p=_PROBS)
        walk = np.concatenate([start, deltas], axis=1).cumsum(axis=1) % V
        anchored = np.where(rng.random(walk.shape) < _ANCHOR_P, 0, walk)
        return anchored.astype(np.int32)
