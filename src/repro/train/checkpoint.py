"""Checkpointing: atomic, bit-exact pytree snapshots as ``.npz``.

``save`` flattens the pytree and writes one compressed-free ``.npz``
per step, through fsync'd temp file + ``os.replace`` + parent-dir
fsync, so neither a killed run nor a machine crash can leave a
half-written (or silently empty-after-rename) checkpoint behind — the
resume path either sees a complete file or the previous step.
Stranded ``*.tmp`` files from a kill mid-write are invisible to
``latest_step`` and overwritten by the next save of that step.  ``restore`` takes a
structure-donor pytree (``like``) and validates leaf count, shapes and
dtypes against it, raising :class:`CheckpointError` on any mismatch so
callers can distinguish "no/incompatible checkpoint" (fall back to
fresh init) from genuine bugs (propagate).

Tensor-parallel runs use the *sharded* layout instead
(:func:`save_sharded`): a ``step_<n>/`` directory holding one npz per
tp shard plus ``manifest.json`` — the layout record (mesh shape,
per-leaf axis rules, layout fingerprint, user metadata).  The
directory is staged under a ``.tmp`` name and renamed into place, so
a complete-looking directory always holds every shard it promises;
anything less (a stranded partial set, a manifest that disagrees with
the restore target) raises :class:`CheckpointError` instead of
loading garbage.  :func:`restore` reassembles the *global* arrays
from the shards, so a later resume may re-shard onto any mesh shape —
or run single-device.  :func:`latest_step`, :func:`restore` and
:func:`load_meta` accept both layouts transparently.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
from pathlib import Path
from typing import Optional

import jax
import numpy as np

__all__ = ["CheckpointError", "latest_step", "save", "save_sharded",
           "restore", "load_meta"]

_STEP_RE = re.compile(r"step_(\d+)\.npz$")
_DIR_RE = re.compile(r"step_(\d+)$")

#: Reserved npz key holding the JSON metadata record (precision-plan
#: fingerprint, backend spec).  Never counted as a pytree leaf.
_META_KEY = "__meta__"

_MANIFEST = "manifest.json"
_FORMAT = "repro-sharded-ckpt"


class CheckpointError(RuntimeError):
    """A checkpoint is absent or does not match the expected pytree."""


def _path(ckpt_dir, step: int) -> Path:
    return Path(ckpt_dir) / f"step_{int(step):08d}.npz"


def _dir_path(ckpt_dir, step: int) -> Path:
    return Path(ckpt_dir) / f"step_{int(step):08d}"


def _shard_name(i: int, num_shards: int) -> str:
    return f"shard_{i:05d}_of_{num_shards:05d}.npz"


def latest_step(ckpt_dir) -> Optional[int]:
    """Highest step with a complete checkpoint in ``ckpt_dir``, or None.

    Both layouts count: ``step_<n>.npz`` files and sharded
    ``step_<n>/`` directories that contain a manifest.  Only exact
    names match — a stranded ``step_<n>.npz.tmp`` (or ``.tmp``
    staging directory) from a killed save is never mistaken for a
    resumable checkpoint (the fullmatch excludes any suffix), and a
    directory without its manifest never got renamed into place by a
    completed save, so it cannot appear here.
    """
    d = Path(ckpt_dir)
    if not d.is_dir():
        return None
    steps = [int(m.group(1)) for f in d.iterdir()
             if (m := _STEP_RE.fullmatch(f.name))]
    steps += [int(m.group(1)) for f in d.iterdir()
              if (m := _DIR_RE.fullmatch(f.name)) and f.is_dir()
              and (f / _MANIFEST).is_file()]
    return max(steps) if steps else None


def save(ckpt_dir, step: int, tree, meta: Optional[dict] = None) -> Path:
    """Write ``tree`` for ``step``; crash-atomic within ``ckpt_dir``.

    ``meta`` (a JSON-serializable dict — notably the active
    precision-plan fingerprint) rides along inside the ``.npz`` under
    a reserved key; :func:`restore` ignores it and :func:`load_meta`
    reads it back, so resume paths can detect a precision-config
    change instead of silently continuing at different numerics.

    ``os.replace`` alone only orders the rename against *other renames*;
    without an ``fsync`` of the temp file the kernel may commit the
    rename before the data blocks, and a crash then leaves a complete-
    looking but empty/truncated ``.npz``.  So: fsync the temp file
    before the rename, then fsync the directory so the rename itself is
    durable.
    """
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    leaves, _ = jax.tree_util.tree_flatten(tree)
    payload = {f"leaf_{i:05d}": np.asarray(leaf)
               for i, leaf in enumerate(leaves)}
    if meta is not None:
        payload[_META_KEY] = np.asarray(json.dumps(meta))
    final = _path(d, step)
    tmp = final.with_name(final.name + ".tmp")
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)  # atomic: readers never see a partial file
    try:
        dir_fd = os.open(d, os.O_RDONLY)
    except OSError:  # pragma: no cover - no dir open (e.g. Windows)
        return final
    try:
        os.fsync(dir_fd)  # make the rename itself durable
    except OSError:  # pragma: no cover - fs without dir fsync
        pass
    finally:
        os.close(dir_fd)
    return final


def _layout_fingerprint(leaves_desc, axis_rules, num_shards: int) -> str:
    """Stable identity of a sharded layout (shapes+dtypes+rules)."""
    blob = json.dumps({"leaves": leaves_desc, "axis_rules": axis_rules,
                       "num_shards": num_shards},
                      sort_keys=True, separators=(",", ":"))
    return "sha256:" + hashlib.sha256(blob.encode()).hexdigest()[:16]


def save_sharded(ckpt_dir, step: int, tree, specs, mesh,
                 meta: Optional[dict] = None) -> Path:
    """Write ``tree`` as per-shard npz files + a layout manifest.

    ``specs`` is a PartitionSpec pytree matching ``tree`` (the LM axis
    rules from :mod:`repro.shard.rules`); ``mesh`` supplies the axis
    sizes.  Leaves whose spec names a mesh axis are sliced along that
    dimension, one block per shard file; replicated leaves are stored
    once, in shard 0.  The manifest records the mesh shape, per-leaf
    axis rules, a layout fingerprint, and ``meta`` (same contract as
    :func:`save`'s).

    Crash-atomic like :func:`save`: every file is fsync'd into a
    ``.tmp`` staging directory which is then renamed over the final
    ``step_<n>/`` name — readers never see a partial shard set under
    the real name.
    """
    from repro.shard.rules import specs_to_rules

    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    leaves, _ = jax.tree_util.tree_flatten(tree)
    leaves = [np.asarray(leaf) for leaf in leaves]
    rules = specs_to_rules(specs, tree)
    axes = {a for rule in rules for a in rule if a is not None}
    if len(axes) > 1:
        raise CheckpointError(
            f"sharded save supports one sharded axis, got {sorted(axes)}")
    shard_axis = axes.pop() if axes else None
    num_shards = dict(mesh.shape)[shard_axis] if shard_axis else 1
    leaves_desc = [{"shape": list(leaf.shape), "dtype": str(leaf.dtype)}
                   for leaf in leaves]
    manifest = {
        "format": _FORMAT, "version": 1, "step": int(step),
        "mesh": {k: int(v) for k, v in dict(mesh.shape).items()},
        "shard_axis": shard_axis, "num_shards": int(num_shards),
        "shards": [_shard_name(i, num_shards)
                   for i in range(num_shards)],
        "axis_rules": rules, "leaves": leaves_desc,
        "fingerprint": _layout_fingerprint(leaves_desc, rules,
                                           num_shards),
        "meta": meta if meta is not None else {},
    }

    final = _dir_path(d, step)
    tmp = final.with_name(final.name + ".tmp")
    if tmp.exists():  # stranded staging dir from a killed save
        for f in tmp.iterdir():
            f.unlink()
        tmp.rmdir()
    tmp.mkdir()

    def _write(path: Path, writer) -> None:
        with open(path, "wb") as f:
            writer(f)
            f.flush()
            os.fsync(f.fileno())

    for i in range(num_shards):
        payload = {}
        for li, (leaf, rule) in enumerate(zip(leaves, rules)):
            dims = [di for di, a in enumerate(rule) if a is not None]
            if dims:
                di = dims[0]
                block = leaf.shape[di] // num_shards
                payload[f"leaf_{li:05d}"] = np.take(
                    leaf, range(i * block, (i + 1) * block), axis=di)
            elif i == 0:
                payload[f"leaf_{li:05d}"] = leaf
        _write(tmp / _shard_name(i, num_shards),
               lambda f, p=payload: np.savez(f, **p))
    _write(tmp / _MANIFEST,
           lambda f: f.write(json.dumps(manifest, indent=1,
                                        sort_keys=True).encode()))

    if final.is_dir():  # re-save of the same step: replace wholesale
        for f in final.iterdir():
            f.unlink()
        final.rmdir()
    os.replace(tmp, final)
    try:
        dir_fd = os.open(d, os.O_RDONLY)
    except OSError:  # pragma: no cover - no dir open (e.g. Windows)
        return final
    try:
        os.fsync(dir_fd)  # make the rename itself durable
    except OSError:  # pragma: no cover - fs without dir fsync
        pass
    finally:
        os.close(dir_fd)
    return final


def _read_manifest(dirpath: Path) -> dict:
    mpath = dirpath / _MANIFEST
    if not mpath.is_file():
        raise CheckpointError(
            f"{dirpath} has no {_MANIFEST} — not a sharded checkpoint "
            "(or an interrupted one that should have stayed .tmp)")
    try:
        manifest = json.loads(mpath.read_text())
    except json.JSONDecodeError as e:
        raise CheckpointError(f"{mpath}: invalid JSON ({e})") from None
    if not isinstance(manifest, dict) \
            or manifest.get("format") != _FORMAT:
        raise CheckpointError(f"{mpath}: not a {_FORMAT} manifest")
    needed = {"num_shards", "shards", "axis_rules", "leaves",
              "fingerprint"}
    if missing := needed - manifest.keys():
        raise CheckpointError(f"{mpath}: manifest is missing "
                              f"{sorted(missing)}")
    fp = _layout_fingerprint(manifest["leaves"],
                             manifest["axis_rules"],
                             manifest["num_shards"])
    if fp != manifest["fingerprint"]:
        raise CheckpointError(
            f"{mpath}: layout fingerprint mismatch ({fp} != "
            f"{manifest['fingerprint']}) — manifest edited or "
            "corrupted; refusing to guess the layout")
    return manifest


def _restore_sharded(dirpath: Path, like):
    """Reassemble the global pytree from a ``step_<n>/`` directory."""
    manifest = _read_manifest(dirpath)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    desc, rules = manifest["leaves"], manifest["axis_rules"]
    num_shards = int(manifest["num_shards"])
    if len(desc) != len(leaves_like):
        raise CheckpointError(
            f"{dirpath} holds {len(desc)} leaves, expected "
            f"{len(leaves_like)} — architecture/optimizer mismatch")
    shards = []
    for name in manifest["shards"]:
        spath = dirpath / name
        if not spath.is_file():
            raise CheckpointError(
                f"{dirpath}: shard file {name} is missing — partial "
                f"shard set ({len(manifest['shards'])} expected); "
                "refusing to load garbage")
        shards.append(np.load(spath))
    try:
        loaded = []
        for li, (ref, d, rule) in enumerate(zip(leaves_like, desc,
                                                rules)):
            ref = np.asarray(ref)
            if (tuple(d["shape"]) != ref.shape
                    or np.dtype(d["dtype"]) != ref.dtype):
                raise CheckpointError(
                    f"{dirpath}:leaf_{li:05d} is {d['dtype']}"
                    f"{list(d['shape'])}, expected {ref.dtype}"
                    f"{list(ref.shape)}")
            key = f"leaf_{li:05d}"
            dims = [di for di, a in enumerate(rule) if a is not None]
            if dims:
                parts = []
                for si, sh in enumerate(shards):
                    if key not in sh.files:
                        raise CheckpointError(
                            f"{dirpath}:{manifest['shards'][si]} is "
                            f"missing {key} — truncated shard file")
                    parts.append(sh[key])
                arr = np.concatenate(parts, axis=dims[0])
            else:
                if key not in shards[0].files:
                    raise CheckpointError(
                        f"{dirpath}:{manifest['shards'][0]} is "
                        f"missing {key} — truncated shard file")
                arr = shards[0][key]
            if arr.shape != ref.shape or arr.dtype != ref.dtype:
                raise CheckpointError(
                    f"{dirpath}:{key} reassembles to {arr.dtype}"
                    f"{list(arr.shape)}, expected {ref.dtype}"
                    f"{list(ref.shape)} — axis rules do not match "
                    "the stored blocks")
            loaded.append(jax.numpy.asarray(arr))
    finally:
        for sh in shards:
            sh.close()
    return jax.tree_util.tree_unflatten(treedef, loaded)


def restore(ckpt_dir, step: int, like):
    """Load the ``step`` checkpoint into the structure of ``like``.

    ``like`` supplies the treedef and the expected leaf shapes/dtypes
    (e.g. freshly initialized ``(params, opt_state)``).  Raises
    :class:`CheckpointError` if the file is missing or disagrees with
    ``like`` in leaf count, shape, or dtype.

    Dispatches on layout: a sharded ``step_<n>/`` directory is
    reassembled into global arrays (so the caller may re-shard onto
    any mesh — restore is mesh-agnostic); otherwise the single-file
    ``step_<n>.npz`` path runs.
    """
    dirpath = _dir_path(ckpt_dir, step)
    if dirpath.is_dir():
        return _restore_sharded(dirpath, like)
    path = _path(ckpt_dir, step)
    if not path.exists():
        raise CheckpointError(f"no checkpoint at {path}")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    with np.load(path) as data:
        keys = sorted(k for k in data.files if k != _META_KEY)
        if len(keys) != len(leaves_like):
            raise CheckpointError(
                f"{path} holds {len(keys)} leaves, expected "
                f"{len(leaves_like)} — architecture/optimizer mismatch")
        loaded = []
        for key, ref in zip(keys, leaves_like):
            arr = data[key]
            ref = np.asarray(ref)
            if arr.shape != ref.shape or arr.dtype != ref.dtype:
                raise CheckpointError(
                    f"{path}:{key} is {arr.dtype}{list(arr.shape)}, "
                    f"expected {ref.dtype}{list(ref.shape)}")
            loaded.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, loaded)


def load_meta(ckpt_dir, step: int) -> dict:
    """The metadata dict saved with the ``step`` checkpoint.

    Returns ``{}`` for checkpoints written without metadata (including
    every pre-metadata checkpoint — old files stay restorable), and
    raises :class:`CheckpointError` when the checkpoint itself is
    missing or its metadata is unreadable.  Sharded checkpoints carry
    their metadata in the manifest.
    """
    dirpath = _dir_path(ckpt_dir, step)
    if dirpath.is_dir():
        meta = _read_manifest(dirpath)["meta"]
        if not isinstance(meta, dict):
            raise CheckpointError(
                f"{dirpath}/{_MANIFEST}: metadata record is "
                f"{type(meta).__name__}, expected an object")
        return meta
    path = _path(ckpt_dir, step)
    if not path.exists():
        raise CheckpointError(f"no checkpoint at {path}")
    with np.load(path) as data:
        if _META_KEY not in data.files:
            return {}
        raw = str(data[_META_KEY][()])
    try:
        meta = json.loads(raw)
    except json.JSONDecodeError as e:
        raise CheckpointError(
            f"{path}: metadata record is not valid JSON ({e})") from None
    if not isinstance(meta, dict):
        raise CheckpointError(
            f"{path}: metadata record is {type(meta).__name__}, "
            "expected an object")
    return meta
