"""Checkpointing: atomic, bit-exact pytree snapshots as ``.npz``.

``save`` flattens the pytree and writes one compressed-free ``.npz``
per step, through fsync'd temp file + ``os.replace`` + parent-dir
fsync, so neither a killed run nor a machine crash can leave a
half-written (or silently empty-after-rename) checkpoint behind — the
resume path either sees a complete file or the previous step.
Stranded ``*.tmp`` files from a kill mid-write are invisible to
``latest_step`` and overwritten by the next save of that step.  ``restore`` takes a
structure-donor pytree (``like``) and validates leaf count, shapes and
dtypes against it, raising :class:`CheckpointError` on any mismatch so
callers can distinguish "no/incompatible checkpoint" (fall back to
fresh init) from genuine bugs (propagate).
"""

from __future__ import annotations

import json
import os
import re
from pathlib import Path
from typing import Optional

import jax
import numpy as np

__all__ = ["CheckpointError", "latest_step", "save", "restore",
           "load_meta"]

_STEP_RE = re.compile(r"step_(\d+)\.npz$")

#: Reserved npz key holding the JSON metadata record (precision-plan
#: fingerprint, backend spec).  Never counted as a pytree leaf.
_META_KEY = "__meta__"


class CheckpointError(RuntimeError):
    """A checkpoint is absent or does not match the expected pytree."""


def _path(ckpt_dir, step: int) -> Path:
    return Path(ckpt_dir) / f"step_{int(step):08d}.npz"


def latest_step(ckpt_dir) -> Optional[int]:
    """Highest step with a complete checkpoint in ``ckpt_dir``, or None.

    Only exact ``step_<n>.npz`` names count — in particular a stranded
    ``step_<n>.npz.tmp`` from a killed :func:`save` is never mistaken
    for a resumable checkpoint (the fullmatch excludes any suffix).
    """
    d = Path(ckpt_dir)
    if not d.is_dir():
        return None
    steps = [int(m.group(1)) for f in d.iterdir()
             if (m := _STEP_RE.fullmatch(f.name))]
    return max(steps) if steps else None


def save(ckpt_dir, step: int, tree, meta: Optional[dict] = None) -> Path:
    """Write ``tree`` for ``step``; crash-atomic within ``ckpt_dir``.

    ``meta`` (a JSON-serializable dict — notably the active
    precision-plan fingerprint) rides along inside the ``.npz`` under
    a reserved key; :func:`restore` ignores it and :func:`load_meta`
    reads it back, so resume paths can detect a precision-config
    change instead of silently continuing at different numerics.

    ``os.replace`` alone only orders the rename against *other renames*;
    without an ``fsync`` of the temp file the kernel may commit the
    rename before the data blocks, and a crash then leaves a complete-
    looking but empty/truncated ``.npz``.  So: fsync the temp file
    before the rename, then fsync the directory so the rename itself is
    durable.
    """
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    leaves, _ = jax.tree_util.tree_flatten(tree)
    payload = {f"leaf_{i:05d}": np.asarray(leaf)
               for i, leaf in enumerate(leaves)}
    if meta is not None:
        payload[_META_KEY] = np.asarray(json.dumps(meta))
    final = _path(d, step)
    tmp = final.with_name(final.name + ".tmp")
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)  # atomic: readers never see a partial file
    try:
        dir_fd = os.open(d, os.O_RDONLY)
    except OSError:  # pragma: no cover - no dir open (e.g. Windows)
        return final
    try:
        os.fsync(dir_fd)  # make the rename itself durable
    except OSError:  # pragma: no cover - fs without dir fsync
        pass
    finally:
        os.close(dir_fd)
    return final


def restore(ckpt_dir, step: int, like):
    """Load the ``step`` checkpoint into the structure of ``like``.

    ``like`` supplies the treedef and the expected leaf shapes/dtypes
    (e.g. freshly initialized ``(params, opt_state)``).  Raises
    :class:`CheckpointError` if the file is missing or disagrees with
    ``like`` in leaf count, shape, or dtype.
    """
    path = _path(ckpt_dir, step)
    if not path.exists():
        raise CheckpointError(f"no checkpoint at {path}")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    with np.load(path) as data:
        keys = sorted(k for k in data.files if k != _META_KEY)
        if len(keys) != len(leaves_like):
            raise CheckpointError(
                f"{path} holds {len(keys)} leaves, expected "
                f"{len(leaves_like)} — architecture/optimizer mismatch")
        loaded = []
        for key, ref in zip(keys, leaves_like):
            arr = data[key]
            ref = np.asarray(ref)
            if arr.shape != ref.shape or arr.dtype != ref.dtype:
                raise CheckpointError(
                    f"{path}:{key} is {arr.dtype}{list(arr.shape)}, "
                    f"expected {ref.dtype}{list(ref.shape)}")
            loaded.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, loaded)


def load_meta(ckpt_dir, step: int) -> dict:
    """The metadata dict saved with the ``step`` checkpoint.

    Returns ``{}`` for checkpoints written without metadata (including
    every pre-metadata checkpoint — old files stay restorable), and
    raises :class:`CheckpointError` when the checkpoint itself is
    missing or its metadata is unreadable.
    """
    path = _path(ckpt_dir, step)
    if not path.exists():
        raise CheckpointError(f"no checkpoint at {path}")
    with np.load(path) as data:
        if _META_KEY not in data.files:
            return {}
        raw = str(data[_META_KEY][()])
    try:
        meta = json.loads(raw)
    except json.JSONDecodeError as e:
        raise CheckpointError(
            f"{path}: metadata record is not valid JSON ({e})") from None
    if not isinstance(meta, dict):
        raise CheckpointError(
            f"{path}: metadata record is {type(meta).__name__}, "
            "expected an object")
    return meta
