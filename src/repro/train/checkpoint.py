"""Checkpointing: atomic, bit-exact pytree snapshots as ``.npz``.

``save`` flattens the pytree and writes one compressed-free ``.npz``
per step, through fsync'd temp file + ``os.replace`` + parent-dir
fsync, so neither a killed run nor a machine crash can leave a
half-written (or silently empty-after-rename) checkpoint behind — the
resume path either sees a complete file or the previous step.
Stranded ``*.tmp`` files from a kill mid-write are invisible to
``latest_step`` and overwritten by the next save of that step.  ``restore`` takes a
structure-donor pytree (``like``) and validates leaf count, shapes and
dtypes against it, raising :class:`CheckpointError` on any mismatch so
callers can distinguish "no/incompatible checkpoint" (fall back to
fresh init) from genuine bugs (propagate).
"""

from __future__ import annotations

import os
import re
from pathlib import Path
from typing import Optional

import jax
import numpy as np

__all__ = ["CheckpointError", "latest_step", "save", "restore"]

_STEP_RE = re.compile(r"step_(\d+)\.npz$")


class CheckpointError(RuntimeError):
    """A checkpoint is absent or does not match the expected pytree."""


def _path(ckpt_dir, step: int) -> Path:
    return Path(ckpt_dir) / f"step_{int(step):08d}.npz"


def latest_step(ckpt_dir) -> Optional[int]:
    """Highest step with a complete checkpoint in ``ckpt_dir``, or None.

    Only exact ``step_<n>.npz`` names count — in particular a stranded
    ``step_<n>.npz.tmp`` from a killed :func:`save` is never mistaken
    for a resumable checkpoint (the fullmatch excludes any suffix).
    """
    d = Path(ckpt_dir)
    if not d.is_dir():
        return None
    steps = [int(m.group(1)) for f in d.iterdir()
             if (m := _STEP_RE.fullmatch(f.name))]
    return max(steps) if steps else None


def save(ckpt_dir, step: int, tree) -> Path:
    """Write ``tree`` for ``step``; crash-atomic within ``ckpt_dir``.

    ``os.replace`` alone only orders the rename against *other renames*;
    without an ``fsync`` of the temp file the kernel may commit the
    rename before the data blocks, and a crash then leaves a complete-
    looking but empty/truncated ``.npz``.  So: fsync the temp file
    before the rename, then fsync the directory so the rename itself is
    durable.
    """
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    leaves, _ = jax.tree_util.tree_flatten(tree)
    payload = {f"leaf_{i:05d}": np.asarray(leaf)
               for i, leaf in enumerate(leaves)}
    final = _path(d, step)
    tmp = final.with_name(final.name + ".tmp")
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)  # atomic: readers never see a partial file
    try:
        dir_fd = os.open(d, os.O_RDONLY)
    except OSError:  # pragma: no cover - no dir open (e.g. Windows)
        return final
    try:
        os.fsync(dir_fd)  # make the rename itself durable
    except OSError:  # pragma: no cover - fs without dir fsync
        pass
    finally:
        os.close(dir_fd)
    return final


def restore(ckpt_dir, step: int, like):
    """Load the ``step`` checkpoint into the structure of ``like``.

    ``like`` supplies the treedef and the expected leaf shapes/dtypes
    (e.g. freshly initialized ``(params, opt_state)``).  Raises
    :class:`CheckpointError` if the file is missing or disagrees with
    ``like`` in leaf count, shape, or dtype.
    """
    path = _path(ckpt_dir, step)
    if not path.exists():
        raise CheckpointError(f"no checkpoint at {path}")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    with np.load(path) as data:
        keys = sorted(data.files)
        if len(keys) != len(leaves_like):
            raise CheckpointError(
                f"{path} holds {len(keys)} leaves, expected "
                f"{len(leaves_like)} — architecture/optimizer mismatch")
        loaded = []
        for key, ref in zip(keys, leaves_like):
            arr = data[key]
            ref = np.asarray(ref)
            if arr.shape != ref.shape or arr.dtype != ref.dtype:
                raise CheckpointError(
                    f"{path}:{key} is {arr.dtype}{list(arr.shape)}, "
                    f"expected {ref.dtype}{list(ref.shape)}")
            loaded.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, loaded)
