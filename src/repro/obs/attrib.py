"""Per-site cost attribution: where the wall time and INT8 GEMMs go.

The tuner decides *per site* how many splits to spend; this module
answers the follow-up question — which sites are actually worth
retuning.  It joins three things the telemetry stream already records:

* ``site_decl`` events — the static facts (m, k, n, batch, mult,
  splits, dtype) of every offloaded site;
* ``site_exec`` counts — how often each site really executed (scan
  iterations and mesh shards each count);
* tracer spans — the measured wall time of the run's hot loop
  (``train_step`` / ``prefill`` / ``decode`` spans).

and prices each site with the :mod:`repro.kernels.tile_model` analytic
costs: INT8 pair-GEMMs, modeled MXU cycles, and modeled HBM bytes per
execution.  Measured wall time is then *attributed* across sites in
proportion to their modeled bottleneck time (the two-resource roofline:
``max(mxu_cycles / clock, hbm_bytes / bw)``) — giving rows like

    site scan0/dot1: 38% wall, 52% INT8 GEMMs, s=6 -> s=4 saves 40%

The demotion column is the actionable part: dropping a site's split
count by 2 removes ``pairs(s) - pairs(s-2)`` pair-GEMMs per execution,
and the row reports that saving against the whole run.

Entry points: :func:`attribution` (events -> ranked
:class:`AttribRow` list), :func:`publish` (rows -> registry gauges so
``/metrics`` scrapes carry the shares live), and
``python -m repro.obs attrib <dir>`` in :mod:`repro.obs.cli`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

__all__ = ["AttribRow", "attribution", "publish", "WALL_SPAN_NAMES"]

#: Span names that measure the hot loop.  When a run recorded any of
#: these, their total duration is the wall time attributed across
#: sites; otherwise every span counts (a bare offload microbenchmark).
WALL_SPAN_NAMES = ("train_step", "prefill", "decode", "decode_tick",
                   "step", "generate")

#: Demotion step suggested per site: splits drop by 2 (one accuracy
#: notch in the tuner's ladder), floored at 1.
_DEMOTE_BY = 2


@dataclasses.dataclass
class AttribRow:
    """One site's share of the run, modeled and measured."""

    site: str
    splits: int
    execs: float                  # measured site_exec count
    int8_gemms: float             # pairs(s) * batch * mult * cplx * execs
    mxu_cycles: float             # modeled, whole run
    hbm_bytes: float              # modeled (v2 traffic), whole run
    model_time_s: float           # roofline bottleneck time, whole run
    gemm_share: float             # fraction of all sites' INT8 GEMMs
    wall_share: float             # fraction of measured wall attributed
    wall_s: Optional[float]       # wall_share * measured wall (if any)
    demote_to: int                # suggested splits (s - 2, min 1)
    demote_save_gemms: float      # INT8 GEMMs saved by the demotion
    demote_save_frac: float       # saving / this site's INT8 GEMMs

    def suggestion(self) -> str:
        """The actionable one-liner the table's last column renders."""
        if self.demote_to >= self.splits or self.int8_gemms <= 0:
            return "-"
        return (f"s={self.splits} -> s={self.demote_to} saves "
                f"{self.demote_save_gemms:.3g} INT8 GEMMs "
                f"({100 * self.demote_save_frac:.0f}%)")


def _exec_counts(events: List[dict]) -> Dict[str, float]:
    """Per-site execution counts: the flushed ``site_exec`` counter
    snapshot when the run closed cleanly, else the first-execution
    ``site_exec`` records (a lower bound of 1 per live site)."""
    counts: Dict[str, float] = {}
    for ev in events:
        if (ev.get("type") == "metric" and ev.get("kind") == "counter"
                and ev.get("name") == "site_exec"):
            site = (ev.get("labels") or {}).get("site", "?")
            counts[site] = counts.get(site, 0.0) + float(
                ev.get("value", 0))
    if not counts:
        for ev in events:
            if ev.get("type") == "site_exec":
                site = ev.get("site", "?")
                counts[site] = counts.get(site, 0.0) + 1.0
    return counts


def _measured_wall_s(events: List[dict]) -> Optional[float]:
    """Total hot-loop wall seconds from span events (dur is in us)."""
    spans = [ev for ev in events if ev.get("type") == "span"]
    if not spans:
        return None
    hot = [s for s in spans if s.get("name") in WALL_SPAN_NAMES]
    use = hot or spans
    return sum(float(s.get("dur", 0.0)) for s in use) / 1e6


def attribution(events: List[dict], params=None) -> List[AttribRow]:
    """Rank a run's offloaded sites by attributed cost.

    ``events`` is one run's event list (``read_events`` /
    ``load_runs`` output); ``params`` a
    :class:`repro.kernels.tile_model.TPUParams` (default v5e).  Sites
    that never executed still get a row (execs 0, zero shares) so the
    table shows the full plan; rows sort by attributed wall share,
    then modeled time, then name.
    """
    # Imported here, not at module top: repro.obs stays importable
    # without dragging in the jax-heavy repro.core package.
    from repro.core.ozaki import num_pair_gemms
    from repro.kernels.tile_model import DEFAULT_PARAMS, select_tiles

    params = params or DEFAULT_PARAMS
    execs = _exec_counts(events)
    wall_s = _measured_wall_s(events)

    rows: List[AttribRow] = []
    for ev in events:
        if ev.get("type") != "site_decl" or not ev.get("offloaded"):
            continue
        site = ev.get("site", "?")
        s = int(ev.get("splits") or 0)
        m, k, n = ev.get("m"), ev.get("k"), ev.get("n")
        if s < 1 or not all(isinstance(d, int) and d > 0
                            for d in (m, k, n)):
            continue
        # One site "execution" covers batch * mult GEMM problems, x4
        # when the GEMM is complex (the 3M-free 4-product lowering).
        per_exec = max(int(ev.get("batch") or 1), 1) * max(
            int(ev.get("mult") or 1), 1)
        if str(ev.get("dtype", "")).startswith("complex"):
            per_exec *= 4
        n_exec = execs.get(site, 0.0)
        problems = per_exec * n_exec

        decision = select_tiles(m, k, n, s, params=params)
        pairs = num_pair_gemms(s)
        int8_gemms = pairs * problems
        mxu = (decision.mxu_cycles_step
               * (decision.kernel_invocations or 0) * problems)
        hbm = float((decision.traffic_model.total_v2
                     if decision.traffic_model else 0) * problems)
        model_t = max(mxu / params.clock_hz, hbm / params.hbm_bw)

        demote_to = max(s - _DEMOTE_BY, 1)
        save = (pairs - num_pair_gemms(demote_to)) * problems
        rows.append(AttribRow(
            site=site, splits=s, execs=n_exec, int8_gemms=int8_gemms,
            mxu_cycles=mxu, hbm_bytes=hbm, model_time_s=model_t,
            gemm_share=0.0, wall_share=0.0, wall_s=None,
            demote_to=demote_to, demote_save_gemms=save,
            demote_save_frac=save / int8_gemms if int8_gemms else 0.0))

    total_gemms = sum(r.int8_gemms for r in rows)
    total_model = sum(r.model_time_s for r in rows)
    for r in rows:
        r.gemm_share = (r.int8_gemms / total_gemms
                        if total_gemms else 0.0)
        r.wall_share = (r.model_time_s / total_model
                        if total_model else 0.0)
        r.wall_s = (wall_s * r.wall_share
                    if wall_s is not None else None)
    rows.sort(key=lambda r: (-r.wall_share, -r.model_time_s, r.site))
    return rows


def publish(rows: List[AttribRow], registry) -> None:
    """Mirror the attribution as per-site gauges on a
    :class:`repro.obs.Registry`, so a live ``/metrics`` scrape carries
    the shares without anyone running the CLI."""
    for r in rows:
        registry.gauge("attrib_wall_share", site=r.site).set(
            r.wall_share)
        registry.gauge("attrib_gemm_share", site=r.site).set(
            r.gemm_share)
        registry.gauge("attrib_int8_gemms", site=r.site).set(
            r.int8_gemms)
        registry.gauge("attrib_demote_save_gemms", site=r.site).set(
            r.demote_save_gemms)
