"""NumericsMonitor: runtime drift detection for tuned precision plans.

A precision plan is calibrated *offline* (``repro.tune``), typically at
step 0 — but the paper's own observation is that emulation accuracy
depends on the operator's values, and values move as training moves.
:class:`PlanStaleError` catches *structural* drift (the program's site
set changed); this module is the runtime complement for *numerical*
drift: every Nth train step the monitor re-runs the program with an
instrumented pass that measures the **realized** relative error of a
probe site — the eligible offloaded site with the largest per-step
FLOP volume, i.e. the site whose error the composed budget is most
exposed to — at its *deployed* split count, against a ``dgemm``
reference.  If the realized error of that single site exceeds the
plan's whole end-to-end budget, the composed bound is certainly
violated and a structured warning fires (plus a ``numerics`` JSONL
event and a registry gauge), telling the operator to re-tune.

The instrumented pass reuses the exact offload/calibration machinery:
a recording :class:`~repro.core.backends.GemmBackend` (authoritative,
``supports_vjp=False``) that returns the *native* product — a monitor
check never perturbs anything — and ships the measured error to the
host via ``jax.debug.callback`` following the Calibrator's
np-asarray-first rule (callbacks must never launch jax ops).  Inside
``shard_map``/``pmap`` bodies the error is ``pmax``-shared across the
mesh axes first, so every device reports the same global value.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backends import GemmBackend
from repro.core.intercept import Site, offload
from repro.core.ozaki import ozaki_matmul
from repro.core.precision import PrecisionPolicy

from .log import get_logger

__all__ = ["NumericsMonitor", "NumericsReport"]


@dataclasses.dataclass
class NumericsReport:
    """One drift check: the probe site's realized error vs the budget."""

    step: int
    site: str                  #: probe site (structural name)
    splits: int                #: deployed split count it ran at
    realized_rel: float        #: measured max relative error
    budget: float              #: end-to-end budget it is held against
    drift: bool                #: realized_rel > budget


class _ProbeGemm(GemmBackend):
    """Recording backend: native result out, probe-site error to host."""

    supports_vjp = False
    intercepts_all_sites = True

    def __init__(self, policy: PrecisionPolicy):
        super().__init__("numerics_probe", policy)
        self._meta: Dict[str, Site] = {}
        self.probe_site: Optional[str] = None
        self._lock = threading.Lock()
        self._realized = 0.0
        self._seen = False

    def observe_sites(self, decisions: Dict[str, Site]) -> None:
        self._meta.update(decisions)
        offloaded = [s for s in decisions.values() if s.offloaded]
        if offloaded and self.probe_site is None:
            # Deterministic probe choice: the costliest offloaded site
            # (most FLOPs per step), name as the tie-break.
            self.probe_site = max(offloaded,
                                  key=lambda s: (s.flops, s.name)).name

    def reset(self) -> None:
        with self._lock:
            self._realized = 0.0
            self._seen = False

    def realized(self) -> Optional[float]:
        with self._lock:
            return self._realized if self._seen else None

    def matmul(self, a, b, *, out_dtype=None, num_splits=None,
               site: str = "default"):
        del num_splits  # the deployed (plan) split count is measured
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        native = a @ b
        if site == self.probe_site:
            is_cplx = (jnp.issubdtype(a.dtype, jnp.complexfloating)
                       or jnp.issubdtype(b.dtype, jnp.complexfloating))
            ref_dtype = jnp.complex128 if is_cplx else jnp.float64
            if not jax.config.jax_enable_x64:
                ref_dtype = jnp.complex64 if is_cplx else jnp.float32
            ref = jnp.matmul(a.astype(ref_dtype), b.astype(ref_dtype))
            emul = ozaki_matmul(
                a, b, num_splits=self.policy.splits_for(site),
                accumulator=self.policy.accumulator,
                out_dtype=ref_dtype,
                slice_bits=self.policy.slice_bits)
            denom = jnp.abs(a).astype(jnp.abs(ref).dtype) @ \
                jnp.abs(b).astype(jnp.abs(ref).dtype)
            denom = jnp.where(denom == 0, 1.0, denom)
            err = jnp.max(jnp.abs(emul - ref) / denom)
            meta = self._meta.get(site)
            for axis, _ in (meta.spmd_axes if meta is not None else ()):
                err = jax.lax.pmax(err, axis)

            def tap(e):
                # np-asarray-first: the callback runs on the runtime's
                # callback thread; launching a jax op here deadlocks
                # the single-threaded CPU runtime.
                val = float(np.max(np.asarray(e)))
                with self._lock:
                    self._realized = max(self._realized, val)
                    self._seen = True

            jax.debug.callback(tap, err)
        return (native if out_dtype is None
                else native.astype(out_dtype))


class NumericsMonitor:
    """Sample a training program every Nth step for realized GEMM error.

    Args:
      fn: the program to probe — the exact train step (or loss) the
        run executes, *unwrapped* (the monitor builds its own
        instrumented offload around it).
      plan: the active :class:`repro.tune.PrecisionPlan`; supplies the
        per-site split counts and the error budget.  Applied in
        ignore-unmatched mode so the monitor also works on a site
        subset (e.g. the forward-only loss).
      policy: alternative to ``plan`` — the active
        :class:`~repro.core.PrecisionPolicy` (a ``--backend`` run with
        uniform splits); the budget then defaults to 32 ulps of the
        probed dtype unless given.
      budget: override the end-to-end relative-error budget.
      every: check period in steps (``maybe_check``); 0 disables.
      registry/sink/log: optional telemetry destinations — a
        ``numerics_realized_rel`` gauge, a ``numerics`` JSONL event
        per check, and a structured WARNING on drift.
    """

    def __init__(self, fn, *, plan=None,
                 policy: Optional[PrecisionPolicy] = None,
                 budget: Optional[float] = None, every: int = 25,
                 registry=None, sink=None, log=None):
        if plan is None and policy is None:
            raise ValueError("NumericsMonitor needs a plan or a policy")
        if policy is None:
            policy = PrecisionPolicy.from_plan(
                plan, on_unmatched_site="ignore")
        self.plan = plan
        self.policy = policy
        self.every = int(every)
        self._budget = budget if budget is None else float(budget)
        self.registry = registry
        self.sink = sink
        self.log = log or get_logger("numerics")
        self._probe = _ProbeGemm(policy)
        self._wrapped = offload(fn, policy, backend=self._probe)
        self.last_report: Optional[NumericsReport] = None

    def _resolve_budget(self) -> float:
        if self._budget is not None:
            return self._budget
        if self.plan is not None:
            return float(self.plan.budget)
        name = self._probe.probe_site
        meta = self._probe._meta.get(name) if name else None
        dtype = meta.dtype if meta is not None else jnp.float32
        return 32.0 * float(jnp.finfo(jnp.dtype(dtype)).eps)

    def maybe_check(self, step: int, *args,
                    **kwargs) -> Optional[NumericsReport]:
        """Run :meth:`check` when ``step`` lands on the period."""
        if self.every <= 0 or step % self.every:
            return None
        return self.check(step, *args, **kwargs)

    def check(self, step: int, *args, **kwargs) -> NumericsReport:
        """One instrumented pass; returns (and records) the report.

        The pass computes ``fn`` natively (outputs are discarded — the
        caller's training state is never touched) while the probe site
        additionally runs the deployed emulation against a ``dgemm``
        reference.
        """
        self._probe.reset()
        self._wrapped(*args, **kwargs)
        # Debug callbacks are asynchronous: drain before reading.
        jax.effects_barrier()
        realized = self._probe.realized()
        site = self._probe.probe_site or "<none>"
        splits = (self.policy.splits_for(site)
                  if self._probe.probe_site else 0)
        budget = self._resolve_budget()
        report = NumericsReport(
            step=int(step), site=site, splits=splits,
            realized_rel=float(realized or 0.0), budget=budget,
            drift=bool(realized is not None and realized > budget))
        self.last_report = report
        if self.registry is not None:
            self.registry.gauge("numerics_realized_rel",
                                site=site).set(report.realized_rel)
            if report.drift:
                self.registry.counter("numerics_drift",
                                      site=site).inc()
        if self.sink is not None:
            self.sink.emit("numerics", step=report.step, site=site,
                           splits=splits,
                           realized_rel=report.realized_rel,
                           budget=budget, drift=report.drift)
        if report.drift:
            self.log.warning(
                f"numerics drift at step {step}: site {site} realized "
                f"rel error {report.realized_rel:.3e} exceeds the "
                f"plan budget {budget:.3e} at s={splits} — the "
                "operands have moved since calibration; re-tune "
                "(launch/train.py --tune / python -m repro.tune)")
        else:
            self.log.debug(
                f"numerics ok at step {step}: site {site} realized "
                f"{report.realized_rel:.3e} <= budget {budget:.3e}")
        return report
