"""Span tracer with Chrome-trace (``chrome://tracing`` / Perfetto) export.

``Tracer.span`` is a context manager measuring host wall time (the
caller is responsible for blocking on device work inside the span —
e.g. the train loop converts the loss to float before the span closes,
and the serve engine ``np.asarray``-s the sampled tokens).  Each closed
span becomes one event:

``{"type": "span", "name": ..., "ts": <us since tracer start>,``
``  "dur": <us>, "tid": <thread id>, "args": {...}}``

When the tracer is built over an :class:`~repro.obs.events.EventSink`
the spans stream straight into the JSONL file (bounded memory over long
runs); without a sink they accumulate in ``tracer.events`` for tests
and ad-hoc use.  :func:`to_chrome` converts span events — from either
source — into the Chrome Trace Event JSON the ``python -m repro.obs
export`` CLI writes: complete ("ph": "X") events that chrome://tracing
and https://ui.perfetto.dev open directly.
"""

from __future__ import annotations

import contextlib
import threading
import time
from typing import List, Optional

__all__ = ["Tracer", "to_chrome"]


class Tracer:
    """Nestable wall-time spans, streamed to a sink or kept in memory."""

    def __init__(self, sink=None):
        self._sink = sink
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        #: retained span events (only when no sink streams them out)
        self.events: List[dict] = []

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    @contextlib.contextmanager
    def span(self, name: str, **args):
        """Time a block; record it as one span event on exit.

        Spans nest naturally (the ``with`` discipline guarantees a
        child closes before — and therefore lies inside — its parent);
        exceptions still close the span, flagged ``error=True``.
        """
        start = self._now_us()
        try:
            yield self
        except BaseException:
            args = {**args, "error": True}
            raise
        finally:
            event = {"name": str(name), "ts": start,
                     "dur": self._now_us() - start,
                     "tid": threading.get_ident() % 10_000_000,
                     "args": args}
            if self._sink is not None:
                self._sink.emit("span", **event)
            else:
                with self._lock:
                    self.events.append({"type": "span", **event})


def to_chrome(events, process_name: str = "repro") -> dict:
    """Span events -> Chrome Trace Event Format JSON document.

    ``events`` is any iterable of event dicts; non-span entries are
    ignored, so a whole JSONL run file can be passed verbatim.  The
    output is the stable subset every trace viewer understands:
    ``traceEvents`` of complete ("ph": "X") events with microsecond
    ``ts``/``dur``, one pid, per-thread tids, plus the process-name
    metadata record.
    """
    trace_events = [{
        "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
        "args": {"name": process_name},
    }]
    for ev in events:
        if ev.get("type") != "span":
            continue
        trace_events.append({
            "name": ev.get("name", "?"),
            "cat": "repro.obs",
            "ph": "X",
            "ts": float(ev.get("ts", 0.0)),
            "dur": float(ev.get("dur", 0.0)),
            "pid": 1,
            "tid": int(ev.get("tid", 0)),
            "args": ev.get("args", {}),
        })
    return {"traceEvents": trace_events, "displayTimeUnit": "ms"}


def write_chrome_trace(events, path) -> "Optional[str]":
    """Serialize :func:`to_chrome` to ``path``; returns the path."""
    import json
    from pathlib import Path

    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(to_chrome(events)) + "\n")
    return str(path)
