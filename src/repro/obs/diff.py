"""Structured cross-run comparison: one answer to "what changed?".

``python -m repro.obs diff RUN_A RUN_B`` takes two recorded runs (two
``events-*.jsonl`` files, or two metrics directories whose latest runs
are used) and joins everything the telemetry stream lets us join:

* **metric series** — the flushed counter/gauge snapshots, keyed by
  ``(name, labels)``, with the B/A ratio; histogram series compare
  count and the p95 estimate.
* **bench rows** — ``bench_row`` events (the CSV mirror from
  ``benchmarks/run.py``): per-row timing ratio plus per-key deltas of
  the parsed ``derived`` payload, with skip state tracked so a row
  that silently *became* a skip is a first-class finding.
* **numerics** — per-site drift counts and worst realized relative
  error, so a precision regression ranks next to a perf one.

Two consumption modes.  Human mode ranks regressions by ratio and
prints tables.  ``--check`` mode is the CI gate and deliberately only
fails on *machine-portable* structural regressions — a bench row that
vanished, a row that newly skips, a counter series that disappeared, a
site whose numerics drift count grew — because raw wall-clock ratios
between a laptop and a CI runner are noise.  Pass ``--max-ratio R`` to
additionally gate timing ratios (same-machine comparisons, and the
injected-regression test).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

__all__ = ["SeriesDelta", "BenchDelta", "NumericsDelta", "DiffReport",
           "diff_runs", "parse_derived"]


def parse_derived(derived: str) -> Dict[str, float]:
    """The numeric view of a bench row's ``;``-separated payload.

    ``key=value`` pairs whose value leads with a float parse (units and
    suffixes like ``20.35TFLOPS`` keep the number); everything else is
    skipped — the diff compares numbers, not prose.
    """
    out: Dict[str, float] = {}
    for part in str(derived or "").split(";"):
        key, sep, val = part.partition("=")
        if not sep:
            continue
        num = ""
        for ch in val.strip():
            if ch.isdigit() or ch in "+-.eE":
                num += ch
            else:
                break
        try:
            out[key.strip()] = float(num)
        except ValueError:
            continue
    return out


def _ratio(a: Optional[float], b: Optional[float]) -> Optional[float]:
    if a is None or b is None or a <= 0:
        return None
    return b / a


@dataclasses.dataclass
class SeriesDelta:
    """One metric series in both runs (or only one of them)."""

    name: str
    labels: Dict[str, str]
    kind: str
    a: Optional[float]            # None when absent from that run
    b: Optional[float]
    ratio: Optional[float]

    @property
    def key(self) -> str:
        lbl = ",".join(f"{k}={v}"
                       for k, v in sorted(self.labels.items()))
        return f"{self.name}{{{lbl}}}" if lbl else self.name


@dataclasses.dataclass
class BenchDelta:
    """One bench row in both runs: timing ratio + derived deltas."""

    name: str
    us_a: Optional[float]
    us_b: Optional[float]
    ratio: Optional[float]        # us_b / us_a; None when not timeable
    skipped_a: bool
    skipped_b: bool
    derived: Dict[str, Tuple[Optional[float], Optional[float]]]

    @property
    def new_skip(self) -> bool:
        return self.skipped_b and not self.skipped_a


@dataclasses.dataclass
class NumericsDelta:
    """One site's numerics health in both runs."""

    site: str
    drift_a: int
    drift_b: int
    realized_a: Optional[float]   # worst realized_rel in the run
    realized_b: Optional[float]


@dataclasses.dataclass
class DiffReport:
    """Everything :func:`diff_runs` found, pre-joined and rankable."""

    run_a: str
    run_b: str
    series: List[SeriesDelta]
    bench: List[BenchDelta]
    numerics: List[NumericsDelta]

    def missing_series(self) -> List[SeriesDelta]:
        return [s for s in self.series if s.b is None]

    def new_series(self) -> List[SeriesDelta]:
        return [s for s in self.series if s.a is None]

    def missing_rows(self) -> List[str]:
        return [b.name for b in self.bench
                if b.us_a is not None and b.us_b is None]

    def new_skips(self) -> List[str]:
        return [b.name for b in self.bench if b.new_skip]

    def regressions(self, threshold: float = 1.0) -> List[BenchDelta]:
        """Timed bench rows whose B/A ratio exceeds ``threshold``,
        worst first — the human-mode headline table."""
        slow = [b for b in self.bench
                if b.ratio is not None and b.ratio > threshold
                and not (b.skipped_a or b.skipped_b)]
        return sorted(slow, key=lambda b: -b.ratio)

    def drift_increases(self) -> List[NumericsDelta]:
        return [n for n in self.numerics if n.drift_b > n.drift_a]

    def failures(self, max_ratio: Optional[float] = None) -> List[str]:
        """The ``--check`` gate: structural regressions (always), plus
        timing ratios beyond ``max_ratio`` when one is given."""
        fails: List[str] = []
        for name in self.missing_rows():
            fails.append(f"bench row {name!r} present in run "
                         f"{self.run_a} but missing from {self.run_b}")
        for name in self.new_skips():
            fails.append(f"bench row {name!r} ran in {self.run_a} but "
                         f"is skipped in {self.run_b}")
        for s in self.missing_series():
            if s.kind == "counter":
                fails.append(f"counter series {s.key} disappeared "
                             f"between runs")
        for n in self.drift_increases():
            fails.append(f"numerics drift count for site {n.site!r} "
                         f"rose {n.drift_a} -> {n.drift_b}")
        if max_ratio is not None:
            for b in self.regressions(max_ratio):
                fails.append(f"bench row {b.name!r} slowed "
                             f"{b.ratio:.2f}x "
                             f"({b.us_a:.0f} -> {b.us_b:.0f} us, "
                             f"max allowed {max_ratio:.2f}x)")
        return fails


def _series_values(events: List[dict]) -> Dict[Tuple, dict]:
    """Last flushed value per (kind-class, name, labels) series.

    Counters/gauges map to their value; histograms to ``count`` and the
    ``p95`` estimate (ratio-compared on count — the stable axis)."""
    out: Dict[Tuple, dict] = {}
    for ev in events:
        if ev.get("type") != "metric":
            continue
        kind = ev.get("kind")
        labels = {str(k): str(v)
                  for k, v in (ev.get("labels") or {}).items()}
        key = (ev.get("name"), tuple(sorted(labels.items())))
        if kind in ("counter", "gauge"):
            out[key] = {"kind": kind, "labels": labels,
                        "value": float(ev.get("value", 0.0))}
        elif kind == "histogram":
            out[key] = {"kind": kind, "labels": labels,
                        "value": float(ev.get("count", 0)),
                        "p95": ev.get("p95")}
    return out


def _bench_rows(events: List[dict]) -> Dict[str, dict]:
    rows: Dict[str, dict] = {}
    for ev in events:
        if ev.get("type") != "bench_row":
            continue
        derived = ev.get("derived") or ""
        nums = ev.get("derived_num")
        if not isinstance(nums, dict):
            nums = parse_derived(derived)
        else:
            nums = {str(k): float(v) for k, v in nums.items()
                    if isinstance(v, (int, float))}
        skipped = ("skipped=" in derived
                   or str(ev.get("name", "")).endswith("_skipped"))
        rows[str(ev.get("name"))] = {
            "us": ev.get("us_per_call"), "skipped": skipped,
            "derived": nums}
    return rows


def _numerics(events: List[dict]) -> Dict[str, dict]:
    sites: Dict[str, dict] = {}
    for ev in events:
        if ev.get("type") != "numerics":
            continue
        site = str(ev.get("site", "?"))
        rec = sites.setdefault(site, {"drift": 0, "realized": None})
        if ev.get("drift"):
            rec["drift"] += 1
        rel = ev.get("realized_rel")
        if rel is not None:
            rec["realized"] = (rel if rec["realized"] is None
                               else max(rec["realized"], float(rel)))
    return sites


def diff_runs(events_a: List[dict], events_b: List[dict], *,
              run_a: str = "A", run_b: str = "B") -> DiffReport:
    """Join two runs' event lists into a :class:`DiffReport`."""
    sa, sb = _series_values(events_a), _series_values(events_b)
    series: List[SeriesDelta] = []
    for key in sorted(set(sa) | set(sb), key=str):
        va, vb = sa.get(key), sb.get(key)
        ref = va or vb
        a = va["value"] if va else None
        b = vb["value"] if vb else None
        series.append(SeriesDelta(
            name=str(key[0]), labels=ref["labels"], kind=ref["kind"],
            a=a, b=b, ratio=_ratio(a, b)))

    ba, bb = _bench_rows(events_a), _bench_rows(events_b)
    bench: List[BenchDelta] = []
    for name in sorted(set(ba) | set(bb)):
        ra = ba.get(name, {"us": None, "skipped": False, "derived": {}})
        rb = bb.get(name, {"us": None, "skipped": False, "derived": {}})
        us_a = ra["us"] if name in ba and not ra["skipped"] else None
        us_b = rb["us"] if name in bb and not rb["skipped"] else None
        derived = {k: (ra["derived"].get(k), rb["derived"].get(k))
                   for k in sorted(set(ra["derived"])
                                   | set(rb["derived"]))}
        bench.append(BenchDelta(
            name=name,
            us_a=ra["us"] if name in ba else None,
            us_b=rb["us"] if name in bb else None,
            ratio=_ratio(us_a, us_b),
            skipped_a=ra["skipped"], skipped_b=rb["skipped"],
            derived=derived))

    na, nb = _numerics(events_a), _numerics(events_b)
    numerics = [NumericsDelta(
        site=site,
        drift_a=na.get(site, {}).get("drift", 0),
        drift_b=nb.get(site, {}).get("drift", 0),
        realized_a=na.get(site, {}).get("realized"),
        realized_b=nb.get(site, {}).get("realized"))
        for site in sorted(set(na) | set(nb))]

    return DiffReport(run_a=run_a, run_b=run_b, series=series,
                      bench=bench, numerics=numerics)
