"""Structured, level-filtered logging for every repro entry point.

``get_logger(name)`` returns a tiny stderr logger whose INFO rendering
is exactly the human-readable ``[name] message`` lines the CLIs printed
before observability existed — swapping ``print(f"[train] ...")`` for
``log.info(...)`` changes the destination stream (stderr, so stdout
stays machine-parseable) and adds level filtering, but not the text the
smoke greps key on.

The threshold comes from the ``REPRO_LOG_LEVEL`` environment variable
(``DEBUG`` / ``INFO`` / ``WARNING`` / ``ERROR``, default ``INFO``) and
is read per call, so tests and operators can flip it without rebuilding
loggers.  A logger optionally tees every rendered record into an
:class:`repro.obs.events.EventSink` (``attach_sink``) so warnings fired
mid-run land in the same JSONL stream as the metrics they explain.
"""

from __future__ import annotations

import os
import sys
import threading
from typing import Dict, Optional

__all__ = ["Logger", "get_logger", "LEVELS"]

#: Level name -> numeric threshold (python-logging compatible values).
LEVELS: Dict[str, int] = {"DEBUG": 10, "INFO": 20, "WARNING": 30,
                          "ERROR": 40}

_DEFAULT_LEVEL = "INFO"


def _threshold() -> int:
    """Resolve ``REPRO_LOG_LEVEL`` each call (monkeypatch-friendly)."""
    name = os.environ.get("REPRO_LOG_LEVEL", _DEFAULT_LEVEL).upper()
    return LEVELS.get(name, LEVELS[_DEFAULT_LEVEL])


class Logger:
    """Minimal leveled logger rendering ``[name] message`` to stderr.

    ``warning``/``error`` records prefix the message with ``WARNING:``/
    ``ERROR:`` so drift warnings stand out in a scrollback the same way
    the pre-obs ad-hoc prints did.
    """

    def __init__(self, name: str, stream=None):
        self.name = name
        self.stream = stream  # None = resolve sys.stderr per record
        self._sink = None

    def attach_sink(self, sink) -> None:
        """Tee rendered records into an EventSink as ``log`` events."""
        self._sink = sink

    def _emit(self, level: str, msg: str) -> None:
        if LEVELS[level] < _threshold():
            return
        tag = "" if level in ("DEBUG", "INFO") else f"{level}: "
        print(f"[{self.name}] {tag}{msg}",
              file=self.stream or sys.stderr, flush=True)
        if self._sink is not None:
            self._sink.emit("log", level=level, logger=self.name,
                            msg=msg)

    def debug(self, msg: str) -> None:
        self._emit("DEBUG", msg)

    def info(self, msg: str) -> None:
        self._emit("INFO", msg)

    def warning(self, msg: str) -> None:
        self._emit("WARNING", msg)

    def error(self, msg: str) -> None:
        self._emit("ERROR", msg)


_loggers: Dict[str, Logger] = {}
_lock = threading.Lock()


def get_logger(name: str, stream=None) -> Logger:
    """Get (or create) the process-wide logger for ``name``.

    ``stream`` overrides the output stream of an existing logger too —
    tests redirect a named logger without touching global state.
    """
    with _lock:
        log = _loggers.get(name)
        if log is None:
            log = _loggers[name] = Logger(name, stream)
        elif stream is not None:
            log.stream = stream
        return log


def reset_logger(name: str, stream: Optional[object] = None) -> Logger:
    """Drop any cached logger for ``name`` and return a fresh one."""
    with _lock:
        _loggers.pop(name, None)
    return get_logger(name, stream)
