"""``python -m repro.obs`` — report, export, attribute, and diff runs.

Four subcommands over a metrics directory of ``events-NNNN.jsonl``
files (as written by :class:`repro.obs.events.MetricsRun`):

``report <dir>``
    Aggregate the latest run (or every run with ``--all``) into
    human-readable tables: the per-site decision/execution table
    (backend, splits, flops, executions, realized numerics error),
    the per-step loss/timing summary, numerics-drift checks, serve
    per-request latencies with p50/p95/p99 estimates, and span
    totals.  Torn JSONL lines (a killed run's final write) are
    counted, not silently skipped.  ``--check`` turns the report into
    a CI gate: exit nonzero unless every *offloaded* declared site
    recorded at least one execution.

``export <dir> [-o trace.json]``
    Convert the run's span events into a Chrome Trace Event JSON file
    that ``chrome://tracing`` and https://ui.perfetto.dev open
    directly.

``attrib <dir>``
    The per-site cost attribution table (:mod:`repro.obs.attrib`):
    measured hot-loop wall time distributed over offloaded sites by
    their tile-model cost, with INT8-GEMM shares and a demote-to
    suggestion per site.

``diff <run_a> <run_b>``
    Structured cross-run comparison (:mod:`repro.obs.diff`): bench
    timing ratios ranked worst-first, metric-series deltas, numerics
    drift changes.  ``--check`` gates machine-portable structural
    regressions for CI; ``--max-ratio R`` additionally gates timing.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import Dict, List, Optional

from .events import load_runs, read_events
from .trace import write_chrome_trace

__all__ = ["main"]


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, bool):
        return "yes" if v else "no"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e5 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)


def _table(headers: List[str], rows: List[List], out) -> None:
    cells = [[_fmt(c) for c in row] for row in rows]
    widths = [max(len(h), *(len(r[i]) for r in cells)) if cells
              else len(h) for i, h in enumerate(headers)]
    print("  " + "  ".join(h.ljust(w)
                           for h, w in zip(headers, widths)), file=out)
    for row in cells:
        print("  " + "  ".join(c.ljust(w)
                               for c, w in zip(row, widths)), file=out)


def _by_type(events: List[dict]) -> Dict[str, List[dict]]:
    grouped: Dict[str, List[dict]] = {}
    for ev in events:
        grouped.setdefault(ev.get("type", "?"), []).append(ev)
    return grouped


def _site_exec_counts(grouped) -> Dict[str, float]:
    """Per-site execution counts: registry snapshot if the run closed
    cleanly, else the first-execution ``site_exec`` records (>= 1)."""
    counts: Dict[str, float] = {}
    for ev in grouped.get("metric", ()):
        if ev.get("kind") == "counter" and ev.get("name") == "site_exec":
            site = (ev.get("labels") or {}).get("site", "?")
            counts[site] = counts.get(site, 0) + float(ev.get("value", 0))
    if not counts:
        for ev in grouped.get("site_exec", ()):
            site = ev.get("site", "?")
            counts[site] = counts.get(site, 0) + 1
    return counts


def _cache_event_counts(grouped) -> Dict[str, float]:
    """Transform-cache resolutions by kind (``miss`` / ``disk_hit`` /
    ``disk_decisions_hit``): registry snapshot if the run closed
    cleanly, else the raw ``transform_cache`` event stream."""
    counts: Dict[str, float] = {}
    for ev in grouped.get("metric", ()):
        if (ev.get("kind") == "counter"
                and ev.get("name") == "transform_cache"):
            kind = (ev.get("labels") or {}).get("result", "?")
            counts[kind] = counts.get(kind, 0) + float(
                ev.get("value", 0))
    if not counts:
        for ev in grouped.get("transform_cache", ()):
            kind = ev.get("result", "?")
            counts[kind] = counts.get(kind, 0) + 1
    return counts


def _last_gauges(grouped, names) -> Dict[str, float]:
    """Final value of each named gauge (metric events are snapshots in
    write order, so the last one wins)."""
    vals: Dict[str, float] = {}
    for ev in grouped.get("metric", ()):
        if ev.get("kind") == "gauge" and ev.get("name") in names:
            vals[ev["name"]] = float(ev.get("value", 0))
    return vals


def _report_run(run_id: str, events: List[dict], out,
                check: bool = False,
                expect_cache_hit: bool = False) -> int:
    grouped = _by_type(events)
    failures = 0
    torn = getattr(events, "dropped", 0)
    suffix = (f" ({torn} torn line(s) dropped — killed run or "
              "truncated copy)") if torn else ""
    print(f"run {run_id}: {len(events)} events{suffix}", file=out)

    decls = grouped.get("site_decl", [])
    execs = _site_exec_counts(grouped)
    # Last realized error per site from the numerics checks.
    realized: Dict[str, float] = {}
    for ev in grouped.get("numerics", ()):
        realized[ev.get("site", "?")] = ev.get("realized_rel")
    if decls:
        print("sites:", file=out)
        rows = []
        for d in sorted(decls, key=lambda d: d.get("site", "")):
            site = d.get("site", "?")
            rows.append([site, d.get("backend") or "native",
                         d.get("splits"), d.get("offloaded"),
                         d.get("dtype"),
                         f"{d.get('lhs_shape')}x{d.get('rhs_shape')}",
                         float(d.get("flops", 0)),
                         execs.get(site), realized.get(site)])
        _table(["site", "backend", "splits", "offload", "dtype",
                "shapes", "flops", "execs", "realized_rel"], rows, out)
        if check:
            for d in decls:
                if d.get("offloaded") and not execs.get(d.get("site")):
                    print(f"CHECK FAIL: offloaded site "
                          f"{d.get('site')!r} recorded no executions",
                          file=out)
                    failures += 1
    elif check:
        print("CHECK FAIL: no site_decl events in this run (was the "
              "run launched without a backend/plan, or killed before "
              "site discovery?)", file=out)
        failures += 1

    steps = grouped.get("step", [])
    if steps:
        losses = [s["loss"] for s in steps if s.get("loss") is not None]
        mss = [s["ms"] for s in steps if s.get("ms") is not None]
        gemms = [s.get("int8_gemms") for s in steps
                 if s.get("int8_gemms") is not None]
        print("steps:", file=out)
        _table(["steps", "first_loss", "last_loss", "mean_ms",
                "int8_gemms/step"],
               [[len(steps),
                 losses[0] if losses else None,
                 losses[-1] if losses else None,
                 sum(mss) / len(mss) if mss else None,
                 gemms[-1] if gemms else None]], out)

    checks = grouped.get("numerics", [])
    if checks:
        print("numerics:", file=out)
        _table(["checks", "site", "splits", "max_realized_rel",
                "budget", "drift"],
               [[len(checks), checks[-1].get("site"),
                 checks[-1].get("splits"),
                 max(c.get("realized_rel", 0.0) for c in checks),
                 checks[-1].get("budget"),
                 sum(1 for c in checks if c.get("drift"))]], out)

    cache_counts = _cache_event_counts(grouped)
    if cache_counts or expect_cache_hit:
        if cache_counts:
            print("transform cache:", file=out)
            _table(["result", "count"],
                   [[k, v] for k, v in sorted(cache_counts.items())],
                   out)
        warm = (cache_counts.get("disk_hit", 0)
                + cache_counts.get("disk_decisions_hit", 0))
        if expect_cache_hit and warm < 1:
            print("CHECK FAIL: --expect-cache-hit but the run "
                  "recorded no disk_hit/disk_decisions_hit transform-"
                  "cache resolutions (cold trace, or warm_cache_dir "
                  "not set?)", file=out)
            failures += 1

    reqs = grouped.get("request", [])
    if reqs:
        def mean(key):
            vals = [r[key] for r in reqs if r.get(key) is not None]
            return sum(vals) / len(vals) if vals else None

        print("serve:", file=out)
        _table(["requests", "mean_admission_s", "mean_prefill_s",
                "mean_ttft_s", "mean_tokens_per_s"],
               [[len(reqs), mean("admission_wait_s"),
                 mean("prefill_s"), mean("ttft_s"),
                 mean("tokens_per_s")]], out)
        kv = _last_gauges(grouped, ("serve_kv_blocks_allocated",
                                    "serve_kv_blocks_hwm",
                                    "serve_kv_block_utilization",
                                    "serve_queue_depth"))
        if kv:
            print("serve kv/queue:", file=out)
            _table(["blocks_allocated", "blocks_hwm",
                    "block_utilization", "queue_depth"],
                   [[kv.get("serve_kv_blocks_allocated"),
                     kv.get("serve_kv_blocks_hwm"),
                     kv.get("serve_kv_block_utilization"),
                     kv.get("serve_queue_depth")]], out)

    hists = [ev for ev in grouped.get("metric", ())
             if ev.get("kind") == "histogram" and ev.get("count")
             and str(ev.get("name", "")).startswith("serve_")]
    if hists:
        print("serve latency quantiles (decade-bucket estimates):",
              file=out)
        _table(["metric", "count", "mean", "p50", "p95", "p99"],
               [[h.get("name"), h.get("count"), h.get("mean"),
                 h.get("p50"), h.get("p95"), h.get("p99")]
                for h in sorted(hists, key=lambda h: h.get("name"))],
               out)

    rows = grouped.get("bench_row", [])
    if rows:
        print("bench:", file=out)
        _table(["name", "us_per_call", "derived"],
               [[r.get("name"), r.get("us_per_call"),
                 r.get("derived")] for r in rows], out)

    spans = grouped.get("span", [])
    if spans:
        agg: Dict[str, List[float]] = {}
        for s in spans:
            agg.setdefault(s.get("name", "?"), []).append(
                float(s.get("dur", 0.0)) / 1e3)
        print("spans:", file=out)
        _table(["name", "count", "total_ms", "mean_ms"],
               [[n, len(d), sum(d), sum(d) / len(d)]
                for n, d in sorted(agg.items())], out)

    if check and not failures:
        print("CHECK OK: every offloaded site recorded executions",
              file=out)
    return failures


def _run_attrib(run_id: str, events: List[dict], out) -> int:
    from .attrib import attribution

    rows = attribution(events)
    print(f"run {run_id}: cost attribution over "
          f"{len(rows)} offloaded site(s)", file=out)
    if not rows:
        print("  (no offloaded site_decl events in this run — was it "
              "launched without a backend/plan?)", file=out)
        return 1
    _table(["site", "s", "execs", "int8_gemms", "gemm%", "wall%",
            "wall_s", "suggestion"],
           [[r.site, r.splits, r.execs, r.int8_gemms,
             f"{100 * r.gemm_share:.1f}", f"{100 * r.wall_share:.1f}",
             r.wall_s, r.suggestion()] for r in rows], out)
    return 0


def _run_diff(args, out) -> int:
    from .diff import diff_runs

    (id_a, ev_a), = _select_runs(args.run_a, False, None).items()
    (id_b, ev_b), = _select_runs(args.run_b, False, None).items()
    report = diff_runs(ev_a, ev_b, run_a=f"{args.run_a}:{id_a}",
                       run_b=f"{args.run_b}:{id_b}")
    print(f"diff {report.run_a} -> {report.run_b}", file=out)

    slower = report.regressions(1.0)
    if slower:
        print("bench rows slower in B (ratio = B/A):", file=out)
        _table(["name", "us_a", "us_b", "ratio"],
               [[b.name, b.us_a, b.us_b, b.ratio]
                for b in slower[:15]], out)
    missing = report.missing_rows()
    if missing:
        print(f"bench rows missing from B: {', '.join(missing)}",
              file=out)
    skips = report.new_skips()
    if skips:
        print(f"bench rows newly skipped in B: {', '.join(skips)}",
              file=out)
    gone = [s.key for s in report.missing_series()]
    if gone:
        print(f"metric series missing from B: {', '.join(gone[:20])}",
              file=out)
    drifted = report.drift_increases()
    if drifted:
        print("numerics drift increases:", file=out)
        _table(["site", "drift_a", "drift_b", "realized_a",
                "realized_b"],
               [[n.site, n.drift_a, n.drift_b, n.realized_a,
                 n.realized_b] for n in drifted], out)
    if not (slower or missing or skips or gone or drifted):
        print("no regressions detected", file=out)

    if not args.check:
        return 0
    failures = report.failures(max_ratio=args.max_ratio)
    for f in failures:
        print(f"CHECK FAIL: {f}", file=out)
    if not failures:
        print("CHECK OK: no structural regressions between runs",
              file=out)
    return 1 if failures else 0


def _select_runs(directory: str, all_runs: bool,
                 run_id: Optional[str]) -> Dict[str, List[dict]]:
    path = Path(directory)
    if path.is_file():
        return {path.stem.partition("-")[2] or path.stem:
                read_events(path)}
    runs = load_runs(path)
    if not runs:
        raise SystemExit(f"no events-*.jsonl runs under {directory}")
    if run_id is not None:
        if run_id not in runs:
            raise SystemExit(f"run {run_id!r} not found; have "
                             f"{sorted(runs)}")
        return {run_id: runs[run_id]}
    if all_runs:
        return runs
    last = sorted(runs)[-1]
    return {last: runs[last]}


def main(argv=None, out=None) -> int:
    out = out or sys.stdout
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Report and export repro telemetry runs.")
    sub = parser.add_subparsers(dest="cmd", required=True)

    rep = sub.add_parser("report", help="aggregate a metrics dir "
                         "into tables")
    rep.add_argument("directory", help="metrics dir (or one "
                     "events-*.jsonl file)")
    rep.add_argument("--all", action="store_true",
                     help="report every run, not just the latest")
    rep.add_argument("--run", default=None, help="report this run id")
    rep.add_argument("--check", action="store_true",
                     help="exit nonzero unless every offloaded site "
                     "recorded at least one execution")
    rep.add_argument("--expect-cache-hit", action="store_true",
                     help="exit nonzero unless the run resolved at "
                     "least one transform-cache entry from the "
                     "persistent on-disk cache (warm start)")

    exp = sub.add_parser("export", help="write a Chrome trace from "
                         "the run's span events")
    exp.add_argument("directory", help="metrics dir (or one "
                     "events-*.jsonl file)")
    exp.add_argument("--all", action="store_true",
                     help="merge spans from every run")
    exp.add_argument("--run", default=None, help="export this run id")
    exp.add_argument("-o", "--output", default="trace.json",
                     help="output path (default trace.json)")

    att = sub.add_parser("attrib", help="per-site cost attribution "
                         "(wall x tile-model) for one run")
    att.add_argument("directory", help="metrics dir (or one "
                     "events-*.jsonl file)")
    att.add_argument("--run", default=None,
                     help="attribute this run id (default: latest)")

    dif = sub.add_parser("diff", help="compare two recorded runs")
    dif.add_argument("run_a", help="baseline: metrics dir (latest "
                     "run) or one events-*.jsonl file")
    dif.add_argument("run_b", help="candidate: metrics dir (latest "
                     "run) or one events-*.jsonl file")
    dif.add_argument("--check", action="store_true",
                     help="exit nonzero on structural regressions "
                     "(missing bench rows, new skips, vanished "
                     "counter series, numerics drift increases)")
    dif.add_argument("--max-ratio", type=float, default=None,
                     help="with --check: also fail bench rows whose "
                     "B/A timing ratio exceeds this (same-machine "
                     "comparisons only — wall clock is not portable)")

    args = parser.parse_args(argv)
    if args.cmd == "diff":
        return _run_diff(args, out)
    runs = _select_runs(args.directory, args.all
                        if args.cmd != "attrib" else False, args.run)

    if args.cmd == "attrib":
        run_id, events = sorted(runs.items())[-1]
        return _run_attrib(run_id, events, out)

    if args.cmd == "report":
        failures = 0
        for i, (run_id, events) in enumerate(sorted(runs.items())):
            if i:
                print("", file=out)
            failures += _report_run(
                run_id, events, out, check=args.check,
                expect_cache_hit=args.expect_cache_hit)
        return 1 if failures else 0

    events = [ev for _, evs in sorted(runs.items()) for ev in evs]
    path = write_chrome_trace(events, args.output)
    n = sum(1 for ev in events if ev.get("type") == "span")
    print(f"wrote {n} spans from {len(runs)} run(s) to {path} "
          "(open in chrome://tracing or https://ui.perfetto.dev)",
          file=out)
    return 0
