"""``repro.obs``: unified telemetry for the BLAS-offload stack.

One lightweight, dependency-free subsystem threaded through every
layer of the repo:

* :mod:`repro.obs.log` — leveled stderr logger (``REPRO_LOG_LEVEL``)
  whose INFO rendering matches the pre-existing ``[train]``-style
  prints;
* :mod:`repro.obs.registry` — labeled counters/gauges/histograms,
  safe to update from ``jax.debug.callback`` threads;
* :mod:`repro.obs.trace` — span tracer with Chrome-trace export;
* :mod:`repro.obs.events` — JSONL structured-event sink and the
  run-scoped :class:`MetricsRun` bundle the entry points construct;
* :mod:`repro.obs.numerics` — :class:`NumericsMonitor`, the runtime
  drift check that closes the calibrate→train loop;
* ``python -m repro.obs`` — the ``report``/``export`` CLI
  (:mod:`repro.obs.cli`).
"""

from .events import EventSink, MetricsRun, json_safe, load_runs, \
    read_events
from .log import LEVELS, Logger, get_logger, reset_logger
from .numerics import NumericsMonitor, NumericsReport
from .registry import Counter, Gauge, Histogram, Registry
from .trace import Tracer, to_chrome, write_chrome_trace

__all__ = [
    "Counter",
    "EventSink",
    "Gauge",
    "Histogram",
    "LEVELS",
    "Logger",
    "MetricsRun",
    "NumericsMonitor",
    "NumericsReport",
    "Registry",
    "Tracer",
    "get_logger",
    "json_safe",
    "load_runs",
    "read_events",
    "reset_logger",
    "to_chrome",
    "write_chrome_trace",
]
