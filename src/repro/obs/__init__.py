"""``repro.obs``: unified telemetry for the BLAS-offload stack.

One lightweight, dependency-free subsystem threaded through every
layer of the repo:

* :mod:`repro.obs.log` — leveled stderr logger (``REPRO_LOG_LEVEL``)
  whose INFO rendering matches the pre-existing ``[train]``-style
  prints;
* :mod:`repro.obs.registry` — labeled counters/gauges/histograms
  (with p50/p95/p99 estimates), safe to update from
  ``jax.debug.callback`` threads;
* :mod:`repro.obs.trace` — span tracer with Chrome-trace export;
* :mod:`repro.obs.events` — JSONL structured-event sink and the
  run-scoped :class:`MetricsRun` bundle the entry points construct;
* :mod:`repro.obs.numerics` — :class:`NumericsMonitor`, the runtime
  drift check that closes the calibrate→train loop;
* :mod:`repro.obs.server` — the live plane: :class:`MetricsServer`
  serves ``/metrics`` in Prometheus text format while a job runs, and
  aggregates multi-process pushes (:func:`push_snapshot`);
* :mod:`repro.obs.slo` — :class:`SLOTracker`, rolling-window
  burn-rate accounting for serve latency targets;
* :mod:`repro.obs.attrib` — per-site cost attribution (measured wall
  × tile-model costs → ranked retuning table);
* :mod:`repro.obs.diff` — structured cross-run regression comparison;
* ``python -m repro.obs`` — the ``report``/``export``/``attrib``/
  ``diff`` CLI (:mod:`repro.obs.cli`).
"""

from .attrib import AttribRow, attribution
from .diff import DiffReport, diff_runs
from .events import EventList, EventSink, MetricsRun, json_safe, \
    load_runs, read_events
from .log import LEVELS, Logger, get_logger, reset_logger
from .numerics import NumericsMonitor, NumericsReport
from .registry import Counter, Gauge, Histogram, Registry
from .server import MetricsServer, push_snapshot, render_prometheus
from .slo import SLOTracker
from .trace import Tracer, to_chrome, write_chrome_trace

__all__ = [
    "AttribRow",
    "Counter",
    "DiffReport",
    "EventList",
    "EventSink",
    "Gauge",
    "Histogram",
    "LEVELS",
    "Logger",
    "MetricsRun",
    "MetricsServer",
    "NumericsMonitor",
    "NumericsReport",
    "Registry",
    "SLOTracker",
    "Tracer",
    "attribution",
    "diff_runs",
    "get_logger",
    "json_safe",
    "load_runs",
    "push_snapshot",
    "read_events",
    "render_prometheus",
    "reset_logger",
    "to_chrome",
    "write_chrome_trace",
]
