"""Metric registry: labeled counters, gauges, and histograms.

The registry is the in-memory side of the telemetry layer: JAX-safe in
the only sense that matters here — metric updates happen on the *host*,
typically from a ``jax.debug.callback`` fired inside a jitted program
(the per-site GEMM hook, the calibration recorder), so every mutating
path takes a lock because the XLA runtime delivers callbacks on its own
threads.  Nothing in this module touches jax; values arriving from
callbacks must already be host-side scalars (the callers follow the
Calibrator's np-asarray-first rule).

Metric identity is ``(kind, name, sorted labels)`` — asking twice for
``registry.counter("site_exec", site="dot0")`` returns the same object,
and asking for the same name+labels as a different kind raises instead
of silently shadowing.  ``Registry.snapshot()`` renders everything as
plain JSON-safe dicts, which is what :class:`repro.obs.events.MetricsRun`
flushes into the JSONL stream at close.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, List, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "Registry"]

#: Histogram bucket upper bounds: ten decades, 1e-6 .. 1e3, plus +inf.
#: Wide enough for seconds-scale latencies and relative errors alike.
BUCKET_BOUNDS: Tuple[float, ...] = tuple(
    10.0 ** e for e in range(-6, 4)) + (math.inf,)


class _Metric:
    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = dict(labels)
        self._lock = threading.Lock()

    def _snap_head(self, kind: str) -> dict:
        return {"kind": kind, "name": self.name,
                "labels": dict(self.labels)}


class Counter(_Metric):
    """Monotonic count; ``inc`` is the only mutation."""

    def __init__(self, name, labels):
        super().__init__(name, labels)
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> dict:
        return {**self._snap_head("counter"), "value": self.value}


class Gauge(_Metric):
    """Last-write-wins scalar (slot occupancy, realized error, ...)."""

    def __init__(self, name, labels):
        super().__init__(name, labels)
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, n: float) -> None:
        with self._lock:
            self._value += float(n)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> dict:
        return {**self._snap_head("gauge"), "value": self.value}


class Histogram(_Metric):
    """count/sum/min/max plus fixed geometric buckets.

    The bucket bounds (:data:`BUCKET_BOUNDS`) are decades from 1e-6 to
    1e3 — coarse, but stable across runs, which is what the report
    tables need.  :meth:`quantile` estimates p50/p95/p99 from those
    buckets by geometric interpolation inside the covering decade,
    clamped to the observed min/max — decade-resolution estimates, which
    is exactly the precision the latency tables and the ``/metrics``
    summary series advertise.
    """

    def __init__(self, name, labels):
        super().__init__(name, labels)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.bucket_counts = [0] * len(BUCKET_BOUNDS)

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)
            for i, bound in enumerate(BUCKET_BOUNDS):
                if v <= bound:
                    self.bucket_counts[i] += 1
                    break

    @property
    def mean(self) -> float:
        with self._lock:
            return self.sum / self.count if self.count else 0.0

    def _quantile_locked(self, q: float) -> float | None:
        """Estimate the q-quantile from the decade buckets (lock held).

        Geometric interpolation inside the covering bucket (its lower
        edge is the previous bound; the first bucket extends one decade
        below its bound), clamped to the observed [min, max] so values
        outside the decade grid — negatives in the first bucket, the
        +inf tail — degrade to the true extrema instead of nonsense.
        """
        if not self.count:
            return None
        target = q * self.count
        cum = 0.0
        for i, cnt in enumerate(self.bucket_counts):
            if cum + cnt >= target and cnt:
                hi = BUCKET_BOUNDS[i]
                if math.isinf(hi):
                    return self.max
                lo = BUCKET_BOUNDS[i - 1] if i else BUCKET_BOUNDS[0] / 10
                frac = (target - cum) / cnt
                est = lo * (hi / lo) ** frac
                return min(max(est, self.min), self.max)
            cum += cnt
        return self.max

    def quantile(self, q: float) -> float | None:
        """Estimated q-quantile (0 < q <= 1); ``None`` when empty."""
        if not 0.0 < q <= 1.0:
            raise ValueError(f"quantile q must be in (0, 1], got {q}")
        with self._lock:
            return self._quantile_locked(q)

    def snapshot(self) -> dict:
        with self._lock:
            return {
                **self._snap_head("histogram"),
                "count": self.count,
                "sum": self.sum,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "mean": self.sum / self.count if self.count else None,
                "p50": self._quantile_locked(0.50),
                "p95": self._quantile_locked(0.95),
                "p99": self._quantile_locked(0.99),
                "buckets": [[("inf" if math.isinf(b) else b), c]
                            for b, c in zip(BUCKET_BOUNDS,
                                            self.bucket_counts)],
            }


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Registry:
    """Get-or-create store of labeled metrics.

    Thread-safe: the get-or-create path locks the registry, each metric
    locks itself.  ``snapshot()`` returns a deterministic (sorted)
    list of JSON-safe dicts.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple, _Metric] = {}

    def _get(self, kind: str, name: str, labels: dict):
        key_labels = tuple(sorted((str(k), str(v))
                           for k, v in labels.items()))
        key = (name, key_labels)
        with self._lock:
            got = self._metrics.get(key)
            if got is not None:
                if not isinstance(got, _KINDS[kind]):
                    raise ValueError(
                        f"metric {name!r} with labels {dict(key_labels)} "
                        f"already registered as "
                        f"{type(got).__name__.lower()}, not {kind}")
                return got
            metric = _KINDS[kind](name, key_labels)
            self._metrics[key] = metric
            return metric

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", name, labels)

    def snapshot(self) -> List[dict]:
        with self._lock:
            metrics = list(self._metrics.items())
        return [m.snapshot()
                for _, m in sorted(metrics, key=lambda kv: kv[0])]
