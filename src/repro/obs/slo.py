"""Rolling-window SLO tracking for the serve path.

PR 9 gave requests a ``latency_target_s`` and the scheduler an edf
policy that orders by deadline — but nothing watched whether the
deadlines were *met* in aggregate.  :class:`SLOTracker` turns each
finished request's latency-vs-target outcome into the standard SRE
burn-rate signal:

    burn = (violating fraction of the window) / (1 - objective)

At ``objective = 0.99``, a window where 1% of requests miss their
target burns at exactly 1.0 — spending error budget precisely as fast
as the SLO allows.  Burn 10 means the budget drains 10x too fast; the
``warn_burn`` / ``page_burn`` thresholds convert that into counters an
alerting rule can fire on (``slo_warn`` / ``slo_page``).

The window is a deque of ``(t, ok)`` outcomes pruned to ``window_s``
seconds on every observation, so the gauge always reflects the recent
past rather than the whole run.  Requests with no latency target are
not observed — an SLO only exists where a target does.

Wired in two places:

* ``serve.Engine`` observes every request's TTFT against its target as
  the request finishes (and seeds the ``slo_burn_rate`` gauge at 0 on
  startup, so the series exists from the first scrape);
* ``serve.Scheduler``'s edf path calls :meth:`late_admission` when it
  admits a request whose deadline already passed while queued —
  admission-time lateness is an SLO violation the engine would
  otherwise only discover a full prefill later.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Deque, Optional, Tuple

from .log import get_logger

__all__ = ["SLOTracker"]

log = get_logger("obs.slo")


class SLOTracker:
    """Burn-rate SLO accounting over a rolling time window.

    Args:
      registry: optional :class:`repro.obs.Registry`; when given, the
        tracker maintains the gauge/counter series below.
      objective: target success fraction (0.99 => 1% error budget).
      window_s: rolling window length in seconds.
      warn_burn / page_burn: burn-rate thresholds; crossing them
        increments ``slo_warn`` / ``slo_page`` (edge-triggered — one
        increment per excursion above the threshold, not per request).
      sink: optional :class:`repro.obs.EventSink`; threshold crossings
        emit ``slo`` events so the JSONL stream records when the
        budget started draining.

    Registry series:
      ``slo_burn_rate`` (gauge) — current burn;
      ``slo_window_requests`` / ``slo_window_violations`` (gauges);
      ``slo_violations`` (counter) — total target misses;
      ``slo_late_admissions`` (counter) — edf admissions past deadline;
      ``slo_warn`` / ``slo_page`` (counters) — threshold crossings.
    """

    def __init__(self, registry=None, *, objective: float = 0.99,
                 window_s: float = 60.0, warn_burn: float = 1.0,
                 page_burn: float = 10.0, sink=None):
        if not 0.0 < objective < 1.0:
            raise ValueError(
                f"objective must be in (0, 1), got {objective}")
        if window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        self.objective = float(objective)
        self.window_s = float(window_s)
        self.warn_burn = float(warn_burn)
        self.page_burn = float(page_burn)
        self.registry = registry
        self.sink = sink
        self._lock = threading.Lock()
        self._window: Deque[Tuple[float, bool]] = collections.deque()
        self._above_warn = False
        self._above_page = False
        if registry is not None:
            # Materialize the series at 0 so a scrape taken before the
            # first request still carries them (the CI gate greps for
            # slo_burn_rate on a freshly started engine).
            registry.gauge("slo_burn_rate").set(0.0)
            registry.gauge("slo_window_requests").set(0.0)
            registry.gauge("slo_window_violations").set(0.0)

    # -- core ----------------------------------------------------------

    def _prune_locked(self, now: float) -> None:
        cutoff = now - self.window_s
        while self._window and self._window[0][0] < cutoff:
            self._window.popleft()

    def _burn_locked(self) -> float:
        if not self._window:
            return 0.0
        bad = sum(1 for _, ok in self._window if not ok)
        frac = bad / len(self._window)
        return frac / (1.0 - self.objective)

    def observe(self, latency_s: float, target_s: Optional[float],
                *, now: Optional[float] = None) -> Optional[float]:
        """Record one finished request; returns the new burn rate.

        ``target_s`` of ``None`` (no SLO on this request) records
        nothing and returns ``None``.
        """
        if target_s is None:
            return None
        now = time.monotonic() if now is None else float(now)
        ok = float(latency_s) <= float(target_s)
        with self._lock:
            self._window.append((now, ok))
            self._prune_locked(now)
            burn = self._burn_locked()
            n = len(self._window)
            bad = sum(1 for _, k in self._window if not k)
            warn_edge = burn > self.warn_burn and not self._above_warn
            page_edge = burn > self.page_burn and not self._above_page
            self._above_warn = burn > self.warn_burn
            self._above_page = burn > self.page_burn
        if self.registry is not None:
            if not ok:
                self.registry.counter("slo_violations").inc()
            self.registry.gauge("slo_burn_rate").set(burn)
            self.registry.gauge("slo_window_requests").set(n)
            self.registry.gauge("slo_window_violations").set(bad)
            if warn_edge:
                self.registry.counter("slo_warn").inc()
            if page_edge:
                self.registry.counter("slo_page").inc()
        if warn_edge or page_edge:
            level = "page" if page_edge else "warn"
            log.warning(f"SLO {level}: burn rate {burn:.2f} "
                        f"({bad}/{n} requests over target in the last "
                        f"{self.window_s:.0f}s, objective "
                        f"{self.objective})")
            if self.sink is not None:
                self.sink.emit("slo", level=level, burn=burn,
                               window_requests=n,
                               window_violations=bad,
                               objective=self.objective)
        return burn

    def late_admission(self, overdue_s: float) -> None:
        """The scheduler's edf hook: a request was admitted
        ``overdue_s`` seconds after its latency deadline had already
        expired in the queue — a violation in the making that the
        burn rate should not have to wait a prefill to see."""
        if self.registry is not None:
            self.registry.counter("slo_late_admissions").inc()
        if self.sink is not None:
            self.sink.emit("slo", level="late_admission",
                           overdue_s=float(overdue_s))

    # -- introspection -------------------------------------------------

    @property
    def burn_rate(self) -> float:
        with self._lock:
            self._prune_locked(time.monotonic())
            return self._burn_locked()

    def window_counts(self) -> Tuple[int, int]:
        """(requests, violations) currently inside the window."""
        with self._lock:
            self._prune_locked(time.monotonic())
            bad = sum(1 for _, ok in self._window if not ok)
            return len(self._window), bad
