"""JSONL structured-event sink and the run-scoped ``MetricsRun`` bundle.

Every telemetry record — per-site GEMM executions, per-step loss and
timing, numerics-drift checks, serve per-request latencies, tracer
spans, registry snapshots — is one JSON object on one line of a
run-scoped ``events-NNNN.jsonl`` file.  The envelope is uniform::

    {"t": <unix seconds>, "type": <event type>, ...fields}

with types ``run_start``, ``site_decl``, ``site_exec``, ``step``,
``numerics``, ``request``, ``tick``, ``span``, ``metric``,
``bench_row``, ``log``, ``run_end`` (the README catalogs the fields of
each).  ``python -m repro.obs report`` aggregates a directory of these
files into tables; ``python -m repro.obs export`` converts the span
events into a Chrome trace.

:class:`MetricsRun` is the per-invocation bundle the entry points
construct: it allocates the next run file in the metrics directory,
owns one :class:`~repro.obs.registry.Registry` and one
:class:`~repro.obs.trace.Tracer` streaming into the sink, and exposes
``site_event_handler`` — the callable
:func:`repro.core.intercept.offload` accepts as ``on_site_event``,
incrementing a per-site execution counter and (once per site) emitting
the static ``site_exec`` declaration.  Closing the run flushes the
registry snapshot as ``metric`` events, so a file is self-contained.
"""

from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Dict, List, Optional

from .registry import Registry
from .trace import Tracer

__all__ = ["EventList", "EventSink", "MetricsRun", "json_safe",
           "read_events", "load_runs"]


def json_safe(v):
    """Coerce numpy scalars/arrays, dtypes, tuples, paths to JSON types."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, dict):
        return {str(k): json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple, set)):
        return [json_safe(x) for x in v]
    item = getattr(v, "item", None)
    if callable(item):  # numpy / jax scalar (and 0-d arrays)
        try:
            return json_safe(item())
        except (TypeError, ValueError):
            pass
    tolist = getattr(v, "tolist", None)
    if callable(tolist):  # numpy array
        return json_safe(tolist())
    return str(v)


class EventSink:
    """Append-only JSONL writer; thread-safe, line-buffered.

    Callbacks fired from the XLA runtime's threads write here, so every
    emit takes the lock and flushes — a killed run keeps everything
    emitted before the kill.
    """

    def __init__(self, path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._lock = threading.Lock()
        self._fh = open(self.path, "a")
        self._closed = False

    def emit(self, type: str, **fields) -> None:
        record = {"t": time.time(), "type": str(type)}
        record.update({k: json_safe(v) for k, v in fields.items()})
        line = json.dumps(record, sort_keys=True)
        with self._lock:
            if self._closed:
                return
            self._fh.write(line + "\n")
            self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if not self._closed:
                self._closed = True
                self._fh.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def _next_run_id(directory: Path) -> str:
    taken = []
    for p in directory.glob("events-*.jsonl"):
        tail = p.stem.rpartition("-")[2]
        if tail.isdigit():
            taken.append(int(tail))
    return f"{max(taken) + 1 if taken else 0:04d}"


class MetricsRun:
    """One invocation's telemetry: JSONL sink + registry + tracer.

    Args:
      directory: the run-scoped metrics directory; each MetricsRun
        allocates the next ``events-NNNN.jsonl`` inside it, so resumed
        or repeated invocations never clobber earlier runs.
      run_id: override the allocated id (tests).
    """

    def __init__(self, directory, run_id: Optional[str] = None):
        self.directory = Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self.run_id = run_id or _next_run_id(self.directory)
        self.sink = EventSink(self.directory
                              / f"events-{self.run_id}.jsonl")
        self.registry = Registry()
        self.tracer = Tracer(sink=self.sink)
        self._lock = threading.Lock()
        self._declared_exec: set = set()
        self._closed = False
        self.sink.emit("run_start", run_id=self.run_id)

    # -- event helpers -------------------------------------------------

    def event(self, type: str, **fields) -> None:
        self.sink.emit(type, **fields)

    def declare_sites(self, sites) -> None:
        """Emit one ``site_decl`` per Site decision (static facts).

        ``sites`` are :class:`repro.core.Site` records — the exact
        list ``offload(...).sites(...)``/``site_report`` produce, so
        the CI coverage gate can hold ``site_exec`` counts against the
        authoritative site report.
        """
        for s in sites:
            self.sink.emit(
                "site_decl", site=s.name, offloaded=bool(s.offloaded),
                eligible=bool(s.eligible), backend=s.backend,
                splits=int(s.splits), lhs_shape=list(s.lhs_shape),
                rhs_shape=list(s.rhs_shape), dtype=s.dtype.name,
                m=s.m, k=s.k, n=s.n, batch=s.batch, mult=s.mult,
                spmd_axes=list(s.spmd_axes), flops=s.flops,
                reason=s.reason,
                tiles=dict(s.tiles) if getattr(s, "tiles", None) else None)

    def site_event_handler(self):
        """The ``on_site_event`` callable for :func:`repro.core.offload`.

        Called on the host once per *execution* of each offloaded site
        (scan iterations and mesh shards each count): increments the
        ``site_exec`` counter labeled by site name and, on the first
        execution of a site, emits its static ``site_exec`` record —
        so the JSONL stream proves the hook fired even if the process
        dies before the registry snapshot is flushed.
        """

        def handler(payload: dict) -> None:
            site = payload.get("site", "?")
            self.registry.counter("site_exec", site=site).inc()
            with self._lock:
                first = site not in self._declared_exec
                if first:
                    self._declared_exec.add(site)
            if first:
                self.sink.emit("site_exec", **payload)

        return handler

    # -- lifecycle -----------------------------------------------------

    def flush_registry(self) -> None:
        """Write the current registry snapshot as ``metric`` events."""
        for snap in self.registry.snapshot():
            self.sink.emit("metric", **snap)

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self.flush_registry()
        self.sink.emit("run_end", run_id=self.run_id)
        self.sink.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# -- reading (the report/export CLI's input layer) ---------------------


class EventList(list):
    """A list of events that also counts the lines it could NOT parse.

    ``dropped`` is the ``events_torn_lines`` count: malformed JSONL
    lines (a killed run's torn final write, a truncated copy) that
    :func:`read_events` skipped.  It is an attribute rather than a
    second return value so every existing ``for ev in read_events(p)``
    caller keeps working unchanged.
    """

    def __init__(self, events=(), dropped: int = 0):
        super().__init__(events)
        self.dropped = int(dropped)


def read_events(path) -> "EventList":
    """Parse one JSONL file; malformed lines are counted in the
    returned :class:`EventList`'s ``dropped``, not silently lost (a
    killed run may leave a torn final line — the report surfaces how
    many lines that cost)."""
    events = EventList()
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            ev = json.loads(line)
        except json.JSONDecodeError:
            events.dropped += 1
            continue
        if isinstance(ev, dict):
            events.append(ev)
        else:
            events.dropped += 1  # parseable but not an event object
    return events


def load_runs(directory) -> Dict[str, List[dict]]:
    """All runs in a metrics directory: ``{run_id: [events...]}``.

    Run ids are the ``events-<id>.jsonl`` stems, sorted, so the last
    key is the most recent run.
    """
    directory = Path(directory)
    runs: Dict[str, List[dict]] = {}
    for p in sorted(directory.glob("events-*.jsonl")):
        runs[p.stem.partition("-")[2]] = read_events(p)
    return runs
