"""Live pull endpoint: ``/metrics`` in Prometheus text format.

Everything the registry holds — the per-site execution counters, the
serve latency histograms, the SLO burn-rate gauges — becomes visible
*while the job runs*: :class:`MetricsServer` is a stdlib
``http.server`` wrapper (no new dependencies) that a training loop or
serve engine starts on a daemon thread and any Prometheus scraper (or
plain ``curl``) can poll.

Routes:

``GET /metrics``
    The registry rendered in the Prometheus text exposition format
    (version 0.0.4): counters and gauges as plain series, histograms as
    cumulative ``_bucket{le=...}`` series plus ``_sum``/``_count`` and
    estimated ``_quantile{quantile="0.5|0.95|0.99"}`` gauge series from
    the decade buckets.  Label values are escaped per the format
    (``\\`` , ``"`` , newline) — site labels like
    ``shmap0/dot1 [dp=4,tp=2]`` round-trip through a parser.

``GET /healthz``
    JSON liveness: uptime, local series count, pushed sources.

``GET /runs``
    JSON listing of the metrics directory's ``events-NNNN.jsonl`` runs
    (event counts and ``events_torn_lines`` per run), when the server
    was built over one.

``POST /push``
    The aggregator mode: a multi-process mesh job has one scrapeable
    endpoint (usually rank 0's) and every other process periodically
    POSTs its registry snapshot via :func:`push_snapshot`.  Pushed
    series render alongside the local ones with a ``src`` label, so
    per-process counters stay distinguishable and sum server-side in
    the scraper (the standard Prometheus aggregation model).

The handler only reads registry *snapshots* (each metric locks itself),
so scraping never blocks a ``jax.debug.callback`` updating a counter.
"""

from __future__ import annotations

import json
import math
import re
import threading
import time
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from pathlib import Path
from typing import Dict, List, Optional
from urllib.parse import urlsplit

from .log import get_logger

__all__ = ["MetricsServer", "render_prometheus", "push_snapshot"]

log = get_logger("obs.server")

_NAME_BAD = re.compile(r"[^a-zA-Z0-9_:]")

#: The quantile series rendered per histogram (matches the snapshot).
_QUANTILES = (("0.5", "p50"), ("0.95", "p95"), ("0.99", "p99"))


def _name(raw: str) -> str:
    """Sanitize to a legal Prometheus metric/label name."""
    name = _NAME_BAD.sub("_", str(raw))
    return name if not name[:1].isdigit() else "_" + name


def _escape(value) -> str:
    """Escape one label VALUE per the text exposition format."""
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _num(v) -> str:
    if v is None or (isinstance(v, float) and math.isnan(v)):
        return "NaN"
    v = float(v)
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return f"{v:.10g}"


def _labels(labels: dict, *extra) -> str:
    """Render a label set (sorted, plus ``extra`` (k, v) pairs last)."""
    items = sorted((labels or {}).items()) + list(extra)
    if not items:
        return ""
    return ("{" + ",".join(f'{_name(k)}="{_escape(v)}"'
                           for k, v in items) + "}")


def render_prometheus(snapshots: List[dict]) -> str:
    """Registry snapshot dicts -> Prometheus text exposition format.

    ``snapshots`` is any concatenation of
    :meth:`repro.obs.Registry.snapshot` outputs (each entry may carry an
    extra ``src`` key naming the pushed source).  One ``# TYPE`` line
    per metric name; histograms expand into cumulative buckets,
    sum/count, and ``<name>_quantile`` gauge series.
    """
    out: List[str] = []
    typed: set = set()

    def type_line(name: str, kind: str) -> None:
        if name not in typed:
            typed.add(name)
            out.append(f"# TYPE {name} {kind}")

    def order(snap: dict):
        return (snap.get("name", ""), str(snap.get("src", "")),
                sorted((snap.get("labels") or {}).items()))

    for snap in sorted(snapshots, key=order):
        kind = snap.get("kind")
        name = _name(snap.get("name", ""))
        extra = ((("src", snap["src"]),) if snap.get("src") else ())
        labels = snap.get("labels") or {}
        if kind in ("counter", "gauge"):
            type_line(name, kind)
            out.append(f"{name}{_labels(labels, *extra)} "
                       f"{_num(snap.get('value', 0.0))}")
        elif kind == "histogram":
            type_line(name, "histogram")
            cum = 0
            for bound, cnt in snap.get("buckets", ()):
                cum += cnt
                le = "+Inf" if bound == "inf" else _num(bound)
                out.append(f"{name}_bucket"
                           f"{_labels(labels, *extra, ('le', le))} "
                           f"{cum}")
            out.append(f"{name}_sum{_labels(labels, *extra)} "
                       f"{_num(snap.get('sum', 0.0))}")
            out.append(f"{name}_count{_labels(labels, *extra)} "
                       f"{int(snap.get('count', 0))}")
            qname = f"{name}_quantile"
            for q, key in _QUANTILES:
                if snap.get(key) is None:
                    continue
                type_line(qname, "gauge")
                out.append(
                    f"{qname}"
                    f"{_labels(labels, *extra, ('quantile', q))} "
                    f"{_num(snap[key])}")
    return "\n".join(out) + ("\n" if out else "")


def push_snapshot(url: str, source: str, registry,
                  timeout: float = 5.0) -> dict:
    """POST a registry snapshot to an aggregating server's ``/push``.

    ``registry`` is a :class:`repro.obs.Registry` (snapshotted here) or
    an already-rendered snapshot list.  Returns the server's JSON ack.
    The caller owns failure policy — a mesh worker that cannot reach
    the aggregator should log and keep training, so this function
    raises rather than swallowing errors.
    """
    metrics = (registry.snapshot() if hasattr(registry, "snapshot")
               else list(registry))
    body = json.dumps({"source": str(source),
                       "metrics": metrics}).encode()
    if not url.rstrip("/").endswith("/push"):
        url = url.rstrip("/") + "/push"
    req = urllib.request.Request(
        url, data=body, headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        return json.loads(resp.read().decode())


class MetricsServer:
    """Serve a registry live over HTTP; optionally aggregate pushes.

    Args:
      registry: the local :class:`repro.obs.Registry` to expose (may be
        ``None`` for a pure aggregator that only re-serves pushes).
      host/port: bind address; ``port=0`` picks an ephemeral port
        (read it back from :attr:`port` — what the tests do).
      runs_dir: optional metrics directory behind ``GET /runs``.
      stale_s: pushed sources older than this are dropped from
        ``/metrics`` (a crashed worker stops polluting the scrape);
        ``0`` keeps everything forever.
    """

    def __init__(self, registry=None, *, host: str = "127.0.0.1",
                 port: int = 0, runs_dir=None,
                 stale_s: float = 300.0):
        self.registry = registry
        self.runs_dir = Path(runs_dir) if runs_dir else None
        self.stale_s = float(stale_s)
        self._host, self._want_port = host, int(port)
        self._lock = threading.Lock()
        self._pushed: Dict[str, List[dict]] = {}
        self._pushed_at: Dict[str, float] = {}
        self._t0 = time.time()
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    # -- data plane ----------------------------------------------------

    def push(self, source: str, metrics: List[dict]) -> int:
        """Store one source's snapshot (replacing its previous one)."""
        clean = [m for m in metrics
                 if isinstance(m, dict) and m.get("name")]
        with self._lock:
            self._pushed[str(source)] = clean
            self._pushed_at[str(source)] = time.time()
        return len(clean)

    def sources(self) -> List[str]:
        with self._lock:
            return sorted(self._pushed)

    def snapshots(self) -> List[dict]:
        """Local registry snapshot + live pushed snapshots (tagged)."""
        snaps = list(self.registry.snapshot()) if self.registry else []
        now = time.time()
        with self._lock:
            for source in sorted(self._pushed):
                if (self.stale_s
                        and now - self._pushed_at[source] > self.stale_s):
                    continue
                snaps.extend({**m, "src": source}
                             for m in self._pushed[source])
        return snaps

    def render(self) -> str:
        return render_prometheus(self.snapshots())

    def _runs_payload(self) -> dict:
        from .events import read_events

        runs = []
        if self.runs_dir is not None and self.runs_dir.is_dir():
            for p in sorted(self.runs_dir.glob("events-*.jsonl")):
                events = read_events(p)
                runs.append({"run_id": p.stem.partition("-")[2],
                             "events": len(events),
                             "events_torn_lines": events.dropped,
                             "path": str(p)})
        return {"directory": (str(self.runs_dir)
                              if self.runs_dir else None),
                "runs": runs}

    def _health_payload(self) -> dict:
        local = len(self.registry.snapshot()) if self.registry else 0
        return {"status": "ok",
                "uptime_s": round(time.time() - self._t0, 3),
                "series": local,
                "pushed_sources": self.sources()}

    # -- lifecycle -----------------------------------------------------

    def start(self) -> "MetricsServer":
        if self._httpd is not None:
            return self
        server = self

        class Handler(BaseHTTPRequestHandler):
            server_version = "repro-obs"

            def log_message(self, *args):  # quiet: we have a logger
                pass

            def _reply(self, code: int, body: bytes, ctype: str):
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def _json(self, payload: dict, code: int = 200):
                self._reply(code, (json.dumps(payload) + "\n").encode(),
                            "application/json")

            def do_GET(self):
                path = urlsplit(self.path).path
                if path == "/metrics":
                    self._reply(
                        200, server.render().encode(),
                        "text/plain; version=0.0.4; charset=utf-8")
                elif path == "/healthz":
                    self._json(server._health_payload())
                elif path == "/runs":
                    self._json(server._runs_payload())
                else:
                    self._json({"error": f"no route {path!r}; have "
                                "/metrics /healthz /runs"}, code=404)

            def do_POST(self):
                path = urlsplit(self.path).path
                if path != "/push":
                    self._json({"error": f"no POST route {path!r}; "
                                "have /push"}, code=404)
                    return
                try:
                    length = int(self.headers.get("Content-Length", 0))
                    payload = json.loads(
                        self.rfile.read(length).decode())
                    source = str(payload["source"])
                    metrics = payload["metrics"]
                    if not isinstance(metrics, list):
                        raise TypeError("metrics must be a list of "
                                        "snapshot dicts")
                except (KeyError, TypeError, ValueError) as e:
                    self._json({"error": f"bad push payload: {e}"},
                               code=400)
                    return
                n = server.push(source, metrics)
                self._json({"ok": True, "source": source, "series": n})

        self._httpd = ThreadingHTTPServer((self._host, self._want_port),
                                          Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            name="repro-obs-metrics-server", daemon=True)
        self._thread.start()
        log.info(f"metrics server on http://{self._host}:{self.port}"
                 "/metrics")
        return self

    @property
    def port(self) -> int:
        if self._httpd is None:
            return self._want_port
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self._host}:{self.port}"

    def close(self) -> None:
        if self._httpd is None:
            return
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        self._httpd = self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.close()
