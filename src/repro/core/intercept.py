"""Automatic BLAS offload: a jaxpr->jaxpr transform over ``dot_general``.

The paper intercepts BLAS calls of an *unmodified* application at the
linker level and redirects large GEMMs to the INT8 emulation engine.
The JAX analogue implemented here is a program transformation: trace
the user function once per input signature, rewrite every qualifying
``dot_general`` in the resulting :class:`ClosedJaxpr` to run through
the policy's GEMM backend (:mod:`repro.core.backends`), and evaluate
the *transformed* jaxpr on subsequent calls — so ``jax.jit(offload(fn))``
compiles the rewritten program with no per-call re-tracing.

What the transform covers:

* plain 2-D ``dot_general`` (any transposition of the contraction);
* batched and rank-N ``dot_general`` — batch/free/contraction axes are
  normalized to ``(B, M, K) @ (B, K, N)`` by transpose+reshape and the
  2-D backend is ``vmap``-ped over the merged batch axis (loop-free);
* sites inside ``pjit`` / ``remat`` (``jax.checkpoint``) bodies, which
  are inlined transparently;
* sites inside ``scan`` / ``while`` / ``cond`` bodies, which are
  rebuilt with transformed bodies;
* sites inside ``shard_map`` / ``pmap`` bodies (multi-device SPMD):
  the body is rebuilt around the rewriter under the same mesh and
  partition specs (``check_rep=False``); collective-adjacent equations
  are canonicalized — plain collectives re-bind as-is, while the
  replication-rewrite artifacts are undone (``pbroadcast`` dropped,
  ``psum2`` -> ``lax.psum``; replaying them verbatim corrupts the
  transpose rule) — and the size gate sees the *per-shard* operand
  shapes, so every device runs the same Ozaki split schedule a
  single-device run would;
* ``jit``-ted inner functions with ``NamedSharding``-annotated
  arguments: the ``pjit`` body is inlined for site discovery and its
  in/out shardings are re-applied as ``with_sharding_constraint``, so
  the transformed program still partitions the same way under
  ``jax.jit``;
* reverse-mode AD: each offloaded site carries a ``custom_vjp`` whose
  backward pass runs the *same* backend on the transposed operands
  ("emulated backward"), so ``jax.grad`` works through offloaded code.

Functions wrapped in ``jax.custom_jvp``/``jax.custom_vjp`` are left
opaque — rewriting their primal would silently discard the user's
derivative rule — so their internal matmuls stay native.

Site naming is structural and **shared verbatim** between
:func:`site_report` and :func:`offload`: ``dot{i}`` numbers the
``dot_general`` sites of a scope in program order (call-like primitives
are inlined into the enclosing scope), and control-flow/SPMD bodies
extend the path — ``scan0/dot1``, ``while2/cond/dot0``,
``cond1/br0/dot0``, ``shmap0/dot1``, ``pmap0/scan0/dot0``.
``PrecisionPolicy.site_splits`` keys against exactly these names, which
is the paper's "enumerate first, then tune per site" workflow.

Public API
----------

``offload(fn, policy)``
    Drop-in replacement for ``fn`` whose large matmuls run emulated.
    ``offload(fn, policy).sites(*args)`` returns the Site decisions for
    a given input signature without computing.

``site_report(fn, policy)``
    Same-signature function that lists the BLAS-3 sites the transform
    would touch (name, shapes, dtype, decision) instead of computing.

``transform_jaxpr(closed_jaxpr, policy)``
    The raw jaxpr->jaxpr transform: returns ``(transformed, sites)``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import warnings
from collections import OrderedDict, namedtuple
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:  # jax >= 0.4.35 exposes the jaxpr IR under jax.extend.core
    from jax.extend import core as jex_core
except ImportError:  # pragma: no cover - older jax
    from jax import core as jex_core

try:  # not auto-imported by `import jax`
    from jax import export as _jax_export
except ImportError:  # pragma: no cover - very old jax
    _jax_export = None

from .backends import GemmBackend, get_backend
from .precision import PrecisionPolicy

__all__ = ["offload", "site_report", "transform_jaxpr", "Site",
           "CacheInfo", "PersistInfo", "OFFLOAD_CACHE_SIZE"]

# Call-like primitives whose body jaxpr is inlined into the enclosing
# scope: they neither change shapes nor iterate, so their sites share
# the enclosing scope's dot numbering.  ("remat2" is the actual
# primitive behind jax.checkpoint/jax.remat; inlining it only trades
# the rematerialization schedule, not values or derivatives.)
# Control-flow primitives (scan/while/cond) get their own scope path
# and dedicated rebuild handlers below.  Custom-derivative calls
# (custom_jvp_call / custom_vjp_call*) are deliberately NOT inlined:
# their bodies define their own differentiation semantics
# (stop-gradients, stabilized rules), so inlining the primal would
# silently replace the user's rule under jax.grad.  They take the
# default native re-bind and their internal matmuls stay native; wrap
# the function's *caller* if those sites matter.
_INLINE_PRIMITIVES = {"pjit", "closed_call", "remat", "remat2",
                      "checkpoint"}


def _check_overrides(policy: PrecisionPolicy, decisions) -> None:
    """Surface ``site_splits``/``site_backends`` keys that match nothing.

    A typo'd site name would otherwise silently run at the default
    split count — the exact failure mode per-site tuning exists to
    prevent.  ``policy.on_unmatched_site`` picks warn (default),
    raise (strict), or ignore (plans applied to a site subset).
    """
    mode = policy.on_unmatched_site
    if mode == "ignore" or not (policy.site_splits
                                or policy.site_backends):
        return
    if mode not in ("warn", "raise"):
        raise ValueError(
            f"on_unmatched_site must be 'warn', 'raise' or 'ignore', "
            f"got {mode!r}")
    unmatched = policy.unmatched_overrides(decisions)
    if not unmatched:
        return
    msg = (f"per-site override keys {unmatched} match no dot_general "
           f"site in the traced function (sites: "
           f"{sorted(decisions)}); they would silently have no effect")
    if mode == "raise":
        raise ValueError(msg)
    warnings.warn(msg, stacklevel=3)


class Site:
    """One discovered ``dot_general`` site and the decision taken.

    Beyond the decision itself the record carries the static facts the
    tuner (:mod:`repro.tune`) keys on: the normalized extents
    ``m``/``k``/``n``/``batch``, the static trip multiplicity ``mult``
    (how many times one step executes this site — the enclosing
    ``scan`` lengths multiplied out), the enclosing SPMD axes
    ``spmd_axes`` (``(name, size)`` pairs of the ``shard_map``/``pmap``
    meshes the site runs under), the resolved per-site ``backend``
    spec, ``eligible`` — whether the site passed the dtype and size
    gates (a plan-demoted site is eligible but not offloaded) — and,
    for Pallas-family backends, ``tiles``: the analytic tile model's
    block/schedule pick for this site's geometry
    (:meth:`repro.kernels.tile_model.TileDecision.summary`).
    """

    def __init__(self, name: str, lhs_shape, rhs_shape, dtype,
                 offloaded: bool, splits: int, reason: str, *,
                 m: int = 0, k: int = 0, n: int = 0, batch: int = 1,
                 mult: int = 1, spmd_axes=(), backend: str = "",
                 eligible: bool = False, tiles: dict | None = None):
        self.name = name
        self.lhs_shape = tuple(lhs_shape)
        self.rhs_shape = tuple(rhs_shape)
        self.dtype = jnp.dtype(dtype)
        self.offloaded = offloaded
        self.splits = splits
        self.reason = reason
        self.m, self.k, self.n, self.batch = m, k, n, batch
        self.mult = mult
        self.spmd_axes = tuple(spmd_axes)
        self.backend = backend
        self.eligible = eligible
        self.tiles = dict(tiles) if tiles else None

    @property
    def flops(self) -> int:
        """Per-step FLOPs of this site, summed over mesh shards.

        ``2*batch*m*k*n`` per execution, times the static trip
        multiplicity, times the enclosing SPMD axis sizes (every shard
        runs the per-shard GEMM once), times 4 for the complex
        four-real-GEMM decomposition.
        """
        spmd = math.prod(s for _, s in self.spmd_axes)
        cplx = 4 if jnp.issubdtype(self.dtype, jnp.complexfloating) else 1
        return (2 * max(self.batch, 1) * self.m * self.k * self.n
                * self.mult * spmd * cplx)

    @property
    def spmd(self) -> str:
        """Mesh context, e.g. ``"dp=4,tp=2"`` (empty off-mesh)."""
        return ",".join(f"{name}={size}"
                        for name, size in self.spmd_axes)

    def __repr__(self):
        action = (f"offload splits={self.splits}" if self.offloaded
                  else f"native ({self.reason})")
        if self.tiles:
            action += (f" tiles={self.tiles['block_m']}x"
                       f"{self.tiles['block_n']}x{self.tiles['block_k']}")
        mesh = f" [{self.spmd}]" if self.spmd_axes else ""
        return (f"{self.name}{mesh}: {self.lhs_shape} @ "
                f"{self.rhs_shape} {self.dtype.name} -> {action}")


def _subjaxprs(eqn):
    """Yield (jaxpr, consts) for the body of a call-like equation."""
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        sub = eqn.params.get(key)
        if sub is None:
            continue
        if hasattr(sub, "jaxpr"):  # ClosedJaxpr
            yield sub.jaxpr, sub.consts
        else:
            yield sub, []
        return


def _mesh_axes(mesh) -> Tuple[Tuple[str, int], ...]:
    """(name, size) pairs of a shard_map mesh (concrete or abstract)."""
    return tuple((str(name), int(mesh.shape[name]))
                 for name in mesh.axis_names)


def _walk_sites(jaxpr, prefix: str = "", dot_counter=None,
                flow_counter=None, out=None, mult: int = 1,
                spmd=()) -> List[Tuple[Any, str, int, tuple]]:
    """Enumerate ``dot_general`` equations with their structural names.

    This single walker is the naming authority: both :func:`site_report`
    and the offload transform consume its ``(eqn, name, mult, spmd)``
    entries, so the two APIs can never diverge.  ``mult`` is the static
    trip multiplicity of the scope (the product of enclosing ``scan``
    lengths; ``while`` bodies and ``cond`` branches count as one — the
    trip count is dynamic) and ``spmd`` the enclosing SPMD axes as
    ``(name, size)`` pairs, both consumed by the site records the
    tuner calibrates against.
    """
    dot_counter = [0] if dot_counter is None else dot_counter
    flow_counter = [0] if flow_counter is None else flow_counter
    out = [] if out is None else out
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            out.append((eqn, f"{prefix}dot{dot_counter[0]}", mult, spmd))
            dot_counter[0] += 1
        elif prim in _INLINE_PRIMITIVES:
            for sub, _ in _subjaxprs(eqn):
                _walk_sites(sub, prefix, dot_counter, flow_counter, out,
                            mult, spmd)
        elif prim == "shard_map":
            # The body sees *per-shard* shapes: sites inside get their
            # offload decision (and size gate) against the local block,
            # so the per-device Ozaki schedule matches a single-device
            # run on one shard.
            _walk_sites(eqn.params["jaxpr"],
                        f"{prefix}shmap{flow_counter[0]}/", out=out,
                        mult=mult,
                        spmd=spmd + _mesh_axes(eqn.params["mesh"]))
            flow_counter[0] += 1
        elif prim == "xla_pmap":
            body = eqn.params["call_jaxpr"]
            axis = ((str(eqn.params["axis_name"]),
                     int(eqn.params["global_axis_size"])),)
            _walk_sites(getattr(body, "jaxpr", body),
                        f"{prefix}pmap{flow_counter[0]}/", out=out,
                        mult=mult, spmd=spmd + axis)
            flow_counter[0] += 1
        elif prim == "scan":
            body = eqn.params["jaxpr"]
            _walk_sites(body.jaxpr, f"{prefix}scan{flow_counter[0]}/",
                        out=out, mult=mult * int(eqn.params["length"]),
                        spmd=spmd)
            flow_counter[0] += 1
        elif prim == "while":
            pfx = f"{prefix}while{flow_counter[0]}/"
            _walk_sites(eqn.params["cond_jaxpr"].jaxpr, pfx + "cond/",
                        out=out, mult=mult, spmd=spmd)
            _walk_sites(eqn.params["body_jaxpr"].jaxpr, pfx, out=out,
                        mult=mult, spmd=spmd)
            flow_counter[0] += 1
        elif prim == "cond":
            pfx = f"{prefix}cond{flow_counter[0]}/"
            for bi, br in enumerate(eqn.params["branches"]):
                _walk_sites(br.jaxpr, f"{pfx}br{bi}/", out=out,
                            mult=mult, spmd=spmd)
            flow_counter[0] += 1
    return out


def _classify(eqn, policy: PrecisionPolicy, name: str, mult: int = 1,
              spmd=()) -> Site:
    """Decide whether one dot_general equation gets offloaded."""
    lhs_aval, rhs_aval = (v.aval for v in eqn.invars)
    dtype = eqn.outvars[0].aval.dtype
    # The same normalization that will execute (batch dims excluded,
    # free/contraction extents merged) decides the size gate.
    dims = _DotDims(eqn.params["dimension_numbers"],
                    lhs_aval.shape, rhs_aval.shape)
    m, k, n = dims.M, dims.K, dims.N
    geom = dict(m=m, k=k, n=n, batch=dims.B, mult=mult,
                spmd_axes=spmd)

    def skip(reason, eligible=False, backend=""):
        return Site(name, lhs_aval.shape, rhs_aval.shape, dtype,
                    False, 0, reason, eligible=eligible,
                    backend=backend, **geom)

    if not (jnp.issubdtype(dtype, jnp.floating)
            or jnp.issubdtype(dtype, jnp.complexfloating)):
        return skip(f"dtype {jnp.dtype(dtype).name}")
    if min(m, k, n) < policy.min_dim:
        return skip(f"min(m,k,n)={min(m, k, n)} < min_dim={policy.min_dim}")
    backend = policy.backend_for(name)
    if backend == "dgemm":
        # A per-site demotion (typically from a precision plan that
        # found the site pathological): the site passes the gates —
        # it is *eligible*, and counts toward plan fingerprints — but
        # executes native.
        return skip("demoted to dgemm", eligible=True, backend=backend)
    splits = policy.splits_for(name)
    return Site(name, lhs_aval.shape, rhs_aval.shape, dtype,
                True, splits, "", eligible=True, backend=backend,
                tiles=_tile_choice(backend, m, k, n, splits, dtype),
                **geom)


def _tile_choice(backend_spec: str, m, k, n, splits, dtype):
    """Analytic tile pick for Pallas-family sites (None otherwise).

    The model itself never imports Pallas, so the decision is available
    (in reports, plans, obs events) even on hosts that cannot run the
    kernel.
    """
    if not backend_spec.startswith("pallas_int8"):
        return None
    from repro.kernels import tile_model  # deferred: core stays light

    decision = tile_model.select_tiles(
        m, k, n, splits, dtype=dtype,
        fused=backend_spec.endswith(":fused"))
    return decision.summary()


class _DotDims:
    """Normalization of a general ``dot_general`` to ``(B, M, K) @ (B, K, N)``.

    Batch axes merge (in batch-dim order) into a leading ``B``, the
    free/contraction axes merge into ``M``/``K``/``N``.  The inverse
    mappings recover operand-shaped cotangents for the backward pass.
    """

    def __init__(self, dimension_numbers, lhs_shape, rhs_shape):
        (lc, rc), (lb, rb) = dimension_numbers
        lfree = [d for d in range(len(lhs_shape))
                 if d not in lc and d not in lb]
        rfree = [d for d in range(len(rhs_shape))
                 if d not in rc and d not in rb]
        self.lperm = (*lb, *lfree, *lc)
        self.rperm = (*rb, *rc, *rfree)
        self.batch_shape = tuple(lhs_shape[d] for d in lb)
        self.m_shape = tuple(lhs_shape[d] for d in lfree)
        self.k_shape = tuple(lhs_shape[d] for d in lc)
        self.n_shape = tuple(rhs_shape[d] for d in rfree)
        self.has_batch = bool(lb)
        self.B = math.prod(self.batch_shape)
        self.M = math.prod(self.m_shape)
        self.K = math.prod(self.k_shape)
        self.N = math.prod(self.n_shape)

    def _lead(self, *tail):
        return (self.B, *tail) if self.has_batch else tail

    def pack_lhs(self, lhs):
        return jnp.transpose(lhs, self.lperm).reshape(
            self._lead(self.M, self.K))

    def pack_rhs(self, rhs):
        return jnp.transpose(rhs, self.rperm).reshape(
            self._lead(self.K, self.N))

    def pack_out(self, out):  # dot_general output is (batch, lfree, rfree)
        return out.reshape(self._lead(self.M, self.N))

    def unpack_out(self, y):
        return y.reshape(self.batch_shape + self.m_shape + self.n_shape)

    def unpack_lhs(self, dl):
        dl = dl.reshape(self.batch_shape + self.m_shape + self.k_shape)
        return jnp.transpose(dl, np.argsort(self.lperm))

    def unpack_rhs(self, dr):
        dr = dr.reshape(self.batch_shape + self.k_shape + self.n_shape)
        return jnp.transpose(dr, np.argsort(self.rperm))


def _site_dot(backend: GemmBackend, site: Site, dims: "_DotDims",
              out_dtype):
    """Build the backend-routed, AD-aware replacement for one site.

    Forward: normalized operands through the backend (``vmap`` over the
    merged batch axis when present).  Backward (``custom_vjp``): the
    standard matmul cotangents ``dA = g @ B^T`` / ``dB = A^T @ g``,
    also executed by the backend — tunable precision end to end.
    """

    def mm(a2, b2, odt):
        return backend(a2, b2, out_dtype=odt, num_splits=site.splits,
                       site=site.name)

    def bmm(a3, b3, odt):
        if dims.has_batch:
            return jax.vmap(lambda x, y: mm(x, y, odt))(a3, b3)
        return mm(a3, b3, odt)

    def fwd_impl(lhs, rhs):
        y = bmm(dims.pack_lhs(lhs), dims.pack_rhs(rhs), out_dtype)
        return dims.unpack_out(y)

    # Instrumentation backends (the tuner's calibration pass) stage
    # side effects the custom_vjp machinery cannot carry — and their
    # output is never differentiated — so they opt out of the wrapper.
    if not getattr(backend, "supports_vjp", True):
        return fwd_impl

    @jax.custom_vjp
    def dot(lhs, rhs):
        return fwd_impl(lhs, rhs)

    def dot_fwd(lhs, rhs):
        return fwd_impl(lhs, rhs), (lhs, rhs)

    def dot_bwd(res, g):
        lhs, rhs = res
        l3 = dims.pack_lhs(lhs)
        r3 = dims.pack_rhs(rhs)
        g3 = dims.pack_out(g)
        swap = lambda x: jnp.swapaxes(x, -1, -2)  # noqa: E731
        dl = bmm(g3, swap(r3), lhs.dtype)
        dr = bmm(swap(l3), g3, rhs.dtype)
        return dims.unpack_lhs(dl), dims.unpack_rhs(dr)

    dot.defvjp(dot_fwd, dot_bwd)
    return dot


def transform_jaxpr(closed, policy: PrecisionPolicy,
                    backend: GemmBackend | None = None,
                    on_site_event=None):
    """Rewrite ``closed`` (a ``ClosedJaxpr``) for emulated execution.

    Returns ``(transformed, sites)``: a new ``ClosedJaxpr`` with every
    offloaded ``dot_general`` replaced by a backend-routed subgraph
    (wrapped in its ``custom_vjp``), and the :class:`Site` decisions in
    discovery order.  The transform runs once; evaluating the result
    (``jax.core.eval_jaxpr``) never re-traces the user function.

    ``on_site_event`` is the telemetry hook: a host callable receiving
    one static payload dict (site name, backend spec, splits, shapes,
    extents, flops) per *execution* of each offloaded site.  It is
    staged as a ``jax.debug.callback`` **sibling** of the site's
    backend call — never inside the ``custom_vjp`` (debug effects
    cannot stage through custom-derivative rules) — so inside a
    ``scan`` body it fires once per iteration and inside a
    ``shard_map``/``pmap`` body once per mesh shard.  The callback
    deliberately carries **zero** array operands: the payload is
    host-built at transform time, the hook adds no device compute, and
    — load-bearing, not just an optimization — an operand-carrying
    callback inside a loop body is *dropped entirely* by JAX's
    partial-eval when the loop is differentiated, whereas the
    zero-operand form is merely hoisted.  Consequence: under
    reverse-mode AD a loop-body site reports once per step, not once
    per iteration (forward-only programs count exactly).  Handlers run
    on the runtime's callback threads and must follow the
    np-asarray-first rule: never launch jax ops from the handler.
    """
    backend = backend or get_backend(policy.backend, policy=policy)
    sites: List[Site] = []
    decisions: Dict[str, Site] = {}
    for eqn, name, mult, spmd in _walk_sites(closed.jaxpr):
        site = _classify(eqn, policy, name, mult, spmd)
        sites.append(site)
        decisions[name] = site
    _check_overrides(policy, decisions)
    # An instrumentation backend (calibration) sees the full site
    # decisions — shapes, extents, trip multiplicity, SPMD axes —
    # before the first matmul call, which only carries the site name.
    observe = getattr(backend, "observe_sites", None)
    if observe is not None:
        observe(decisions)

    # Per-site backend routing: a site whose resolved spec differs
    # from the policy default (plan promotions, e.g. a single site on
    # the Pallas kernel) gets its own engine; sites on the default
    # spec share the passed-in instance (stateful engines like
    # "adaptive" keep one site cache across signatures).  A backend
    # declaring ``intercepts_all_sites`` (the calibration recorder) is
    # authoritative for every site regardless of per-site specs.
    engines: Dict[str, GemmBackend] = {policy.backend: backend}
    authoritative = getattr(backend, "intercepts_all_sites", False)

    def engine_for(site: Site) -> GemmBackend:
        if authoritative:
            return backend
        spec = site.backend or policy.backend
        if spec not in engines:
            engines[spec] = get_backend(spec, policy=policy)
        return engines[spec]

    def stage_site_event(site: Site) -> None:
        # Static payload, built host-side once per staging; the
        # callback takes zero array operands so it costs nothing on
        # device and cannot trip the np-asarray-first rule itself.
        payload = {
            "site": site.name,
            "backend": site.backend or policy.backend,
            "splits": int(site.splits),
            "lhs_shape": list(site.lhs_shape),
            "rhs_shape": list(site.rhs_shape),
            "dtype": site.dtype.name,
            "m": site.m, "k": site.k, "n": site.n,
            "batch": site.batch, "mult": site.mult,
            "spmd_axes": [list(ax) for ax in site.spmd_axes],
            "flops": site.flops,
            "tiles": dict(site.tiles) if site.tiles else None,
        }
        jax.debug.callback(
            lambda _p=payload: on_site_event(dict(_p)))

    def read_env(env, v):
        return v.val if isinstance(v, jex_core.Literal) else env[v]

    # Decisions are keyed by the structural *name*, and the evaluator
    # re-derives names with the exact counter discipline of
    # _walk_sites.  Keying by equation identity would be wrong: JAX's
    # tracing cache reuses one body jaxpr object (hence the same eqn
    # objects) for every call of a jit-ed inner function, so distinct
    # sites can share an eqn.
    def eval_rewritten(jaxpr, consts: Sequence[Any], args: Sequence[Any],
                       prefix: str = "", dot_counter=None,
                       flow_counter=None):
        dot_counter = [0] if dot_counter is None else dot_counter
        flow_counter = [0] if flow_counter is None else flow_counter
        env = {}
        for var, const in zip(jaxpr.constvars, consts):
            env[var] = const
        for var, arg in zip(jaxpr.invars, args):
            env[var] = arg

        for eqn in jaxpr.eqns:
            invals = [read_env(env, v) for v in eqn.invars]
            prim = eqn.primitive.name
            if prim == "dot_general":
                site = decisions[f"{prefix}dot{dot_counter[0]}"]
                dot_counter[0] += 1
                # An authoritative instrumentation backend must see
                # every *eligible* site — including ones a plan
                # demoted to native — or re-calibration under a
                # from_plan policy would re-promote pathological
                # sites unmeasured.
                if site.offloaded or (authoritative and site.eligible):
                    dims = _DotDims(eqn.params["dimension_numbers"],
                                    site.lhs_shape, site.rhs_shape)
                    fn = _site_dot(engine_for(site), site, dims,
                                   eqn.outvars[0].aval.dtype)
                    if on_site_event is not None and site.offloaded:
                        stage_site_event(site)
                    outvals = [fn(invals[0], invals[1])]
                else:
                    outvals = [eqn.primitive.bind(*invals, **eqn.params)]
            elif prim in _INLINE_PRIMITIVES:
                # Inlining a pjit discards its partitioning params, so
                # NamedSharding annotations on the inner jit are
                # re-applied as sharding constraints around the inlined
                # body — offload(jax.jit(fn, in_shardings=...)) keeps
                # partitioning exactly as the user declared it.
                if prim == "pjit":
                    invals = _apply_shardings(
                        invals, eqn.params.get("in_shardings"))
                outvals = None
                for sub, sub_consts in _subjaxprs(eqn):
                    outvals = eval_rewritten(sub, sub_consts, invals,
                                             prefix, dot_counter,
                                             flow_counter)
                if outvals is None:  # no body found — bind natively
                    outvals = eqn.primitive.bind(*invals, **eqn.params)
                    if not eqn.primitive.multiple_results:
                        outvals = [outvals]
                elif prim == "pjit":
                    outvals = _apply_shardings(
                        outvals, eqn.params.get("out_shardings"))
            elif prim == "shard_map":
                pfx = f"{prefix}shmap{flow_counter[0]}/"
                flow_counter[0] += 1
                outvals = _eval_shard_map(eqn, invals, eval_rewritten,
                                          pfx)
            elif prim == "xla_pmap":
                pfx = f"{prefix}pmap{flow_counter[0]}/"
                flow_counter[0] += 1
                outvals = _eval_pmap(eqn, invals, eval_rewritten, pfx)
            elif prim == "pbroadcast":
                # shard_map's replication-tracking rewrite (check_rep)
                # stages pbroadcast markers into the body; they are
                # physically the identity, and replaying them under the
                # check_rep=False rebuild corrupts the transpose rule —
                # drop them.
                outvals = list(invals)
            elif prim == "psum2":
                # Same story for psum2 (the rewritten psum): replay it
                # as the plain collective so values AND cotangents come
                # out right under the check_rep=False rebuild.  One
                # bind over *all* operands: a bucketed gradient
                # all-reduce stages one multi-operand psum per bucket,
                # and replaying it per operand would silently de-fuse
                # the buckets the overlap path exists to create.
                outvals = list(jax.lax.psum(
                    tuple(invals), tuple(eqn.params["axes"]),
                    axis_index_groups=eqn.params.get(
                        "axis_index_groups")))
            elif prim == "scan":
                pfx = f"{prefix}scan{flow_counter[0]}/"
                flow_counter[0] += 1
                outvals = _eval_scan(eqn, invals, eval_rewritten, pfx)
            elif prim == "while":
                pfx = f"{prefix}while{flow_counter[0]}/"
                flow_counter[0] += 1
                outvals = _eval_while(eqn, invals, eval_rewritten, pfx)
            elif prim == "cond":
                pfx = f"{prefix}cond{flow_counter[0]}/"
                flow_counter[0] += 1
                outvals = _eval_cond(eqn, invals, eval_rewritten, pfx)
            else:
                # Canonical re-bind (same as jax.core.eval_jaxpr):
                # get_bind_params re-wraps staged params — e.g. the
                # jvp/fwd/bwd rules of opaque custom-derivative calls —
                # into bindable form; plain primitives pass through.
                subfuns, bind_params = eqn.primitive.get_bind_params(
                    eqn.params)
                outvals = eqn.primitive.bind(*subfuns, *invals,
                                             **bind_params)
                if not eqn.primitive.multiple_results:
                    outvals = [outvals]
            for var, val in zip(eqn.outvars, outvals):
                env[var] = val

        return [read_env(env, v) for v in jaxpr.outvars]

    def interp(*flat_args):
        return eval_rewritten(closed.jaxpr, closed.consts, flat_args)

    in_specs = [jax.ShapeDtypeStruct(v.aval.shape, v.aval.dtype)
                for v in closed.jaxpr.invars]
    transformed = jax.make_jaxpr(interp)(*in_specs)
    return transformed, sites


def _eval_scan(eqn, invals, eval_body, prefix):
    """Rebuild a ``scan`` with its body routed through the rewriter."""
    p = eqn.params
    nc, ncar = p["num_consts"], p["num_carry"]
    body = p["jaxpr"]
    consts = invals[:nc]
    init = tuple(invals[nc:nc + ncar])
    xs = tuple(invals[nc + ncar:])

    def body_fun(carry, x):
        # Fresh counters per trace of the body: scan may re-trace it
        # (carry fixed-point), and names must restart each time.
        outs = eval_body(body.jaxpr, body.consts, [*consts, *carry, *x],
                         prefix)
        return tuple(outs[:ncar]), tuple(outs[ncar:])

    carry_out, ys = jax.lax.scan(body_fun, init, xs, length=p["length"],
                                 reverse=p["reverse"],
                                 unroll=p.get("unroll", 1))
    return [*carry_out, *ys]


def _eval_while(eqn, invals, eval_body, prefix):
    """Rebuild a ``while`` with cond/body routed through the rewriter."""
    p = eqn.params
    cn, bn = p["cond_nconsts"], p["body_nconsts"]
    cond_jaxpr, body_jaxpr = p["cond_jaxpr"], p["body_jaxpr"]
    cconsts = invals[:cn]
    bconsts = invals[cn:cn + bn]
    init = tuple(invals[cn + bn:])

    def cond_fun(val):
        return eval_body(cond_jaxpr.jaxpr, cond_jaxpr.consts,
                         [*cconsts, *val], prefix + "cond/")[0]

    def body_fun(val):
        return tuple(eval_body(body_jaxpr.jaxpr, body_jaxpr.consts,
                               [*bconsts, *val], prefix))

    return list(jax.lax.while_loop(cond_fun, body_fun, init))


def _eval_cond(eqn, invals, eval_body, prefix):
    """Rebuild a ``cond``/``switch`` with rewritten branches."""
    branches = eqn.params["branches"]
    index, *operands = invals

    def branch_fun(bi, br):
        return lambda *ops: tuple(eval_body(br.jaxpr, br.consts,
                                            list(ops),
                                            f"{prefix}br{bi}/"))

    return list(jax.lax.switch(
        index, [branch_fun(bi, br) for bi, br in enumerate(branches)],
        *operands))


def _apply_shardings(vals, shardings):
    """Constrain ``vals`` to the concrete shardings of a pjit eqn.

    Entries that are not actual :class:`jax.sharding.Sharding` objects
    (``UnspecifiedValue`` placeholders from a plain ``jax.jit``) leave
    the value untouched.
    """
    if shardings is None:
        return vals
    out = []
    for val, sh in zip(vals, shardings):
        if isinstance(sh, jax.sharding.Sharding):
            val = jax.lax.with_sharding_constraint(val, sh)
        out.append(val)
    return out


def _names_to_specs(names_seq, var_seq):
    """shard_map ``in_names``/``out_names`` dicts -> PartitionSpecs."""
    return tuple(
        jax.sharding.PartitionSpec(
            *[names.get(d) for d in range(v.aval.ndim)])
        for names, v in zip(names_seq, var_seq))


def _eval_shard_map(eqn, invals, eval_body, prefix):
    """Rebuild a ``shard_map`` with its body routed through the rewriter.

    The body is re-traced under the original mesh and partition specs
    (recovered from ``in_names``/``out_names``), so per-shard sites run
    the backend on their local block and collectives replay in place.
    ``check_rep=False``: the recorded body already carries the
    replication-rewrite artifacts (``psum2``/``pbroadcast``), which the
    evaluator canonicalizes back to plain collectives — running the
    rewrite machinery again on top of them would double-apply it (and
    it has no rules for the offloaded sites' ``custom_vjp`` wrappers).
    """
    from jax.experimental import shard_map as _shard_map  # deferred

    p = eqn.params
    body = p["jaxpr"]
    in_specs = _names_to_specs(p["in_names"], eqn.invars)
    out_specs = _names_to_specs(p["out_names"], eqn.outvars)

    def body_fun(*args):
        return tuple(eval_body(body, (), list(args), prefix))

    rebuilt = _shard_map.shard_map(
        body_fun, mesh=p["mesh"], in_specs=in_specs,
        out_specs=out_specs, check_rep=False)
    return list(rebuilt(*invals))


def _eval_pmap(eqn, invals, eval_body, prefix):
    """Rebuild a ``pmap`` with its per-device body rewritten."""
    p = eqn.params
    body = p["call_jaxpr"]
    jaxpr = getattr(body, "jaxpr", body)
    consts = getattr(body, "consts", ())

    def body_fun(*args):
        return tuple(eval_body(jaxpr, consts, list(args), prefix))

    rebuilt = jax.pmap(body_fun, axis_name=p["axis_name"],
                       in_axes=p["in_axes"], out_axes=p["out_axes"],
                       devices=p.get("devices"),
                       backend=p.get("backend"))
    return list(rebuilt(*invals))


def _signature(flat_args):
    # Python scalars trace as weakly-typed avals: keep them distinct
    # from same-dtype arrays so a cached transform is never reused
    # across a promotion-semantics boundary.
    return tuple((jnp.shape(x), jnp.result_type(x),
                  isinstance(x, (bool, int, float, complex)))
                 for x in flat_args)


#: ``wrapped.cache_info()`` record, same shape as functools.lru_cache's.
CacheInfo = namedtuple("CacheInfo", ["hits", "misses", "maxsize",
                                     "currsize"])

#: ``wrapped.persist_info()`` record for the on-disk transform cache:
#: ``disk_hits`` — entries restored with a runnable exported program
#: (no re-trace, no re-transform); ``disk_decisions_hits`` — entries
#: whose site decisions were restored and byte-verified but whose
#: program had to be re-traced (no exported artifact on disk);
#: ``disk_misses`` — entries traced fresh and written out.
PersistInfo = namedtuple("PersistInfo", ["disk_hits",
                                         "disk_decisions_hits",
                                         "disk_misses", "directory"])

#: Bumped whenever the persisted payload layout changes; part of the
#: cache key, so stale-format files are simply never looked up.
_PERSIST_FORMAT = 1


def _site_payload(sites: Sequence[Site]) -> list:
    """Site records as plain JSON data (the persisted decision set)."""
    return [{"name": s.name, "lhs_shape": list(s.lhs_shape),
             "rhs_shape": list(s.rhs_shape), "dtype": s.dtype.name,
             "offloaded": bool(s.offloaded), "splits": int(s.splits),
             "reason": s.reason, "m": int(s.m), "k": int(s.k),
             "n": int(s.n), "batch": int(s.batch), "mult": int(s.mult),
             "spmd_axes": [[a, int(x)] for a, x in s.spmd_axes],
             "backend": s.backend, "eligible": bool(s.eligible),
             "tiles": s.tiles} for s in sites]


def _sites_from_payload(payload: list) -> List[Site]:
    return [Site(p["name"], p["lhs_shape"], p["rhs_shape"], p["dtype"],
                 p["offloaded"], p["splits"], p["reason"], m=p["m"],
                 k=p["k"], n=p["n"], batch=p["batch"], mult=p["mult"],
                 spmd_axes=[tuple(a) for a in p["spmd_axes"]],
                 backend=p["backend"], eligible=p["eligible"],
                 tiles=p["tiles"]) for p in payload]


def _sites_bytes(sites: Sequence[Site]) -> bytes:
    """Canonical byte encoding of the decision set.  Two processes that
    take the same decisions produce *identical bytes* — the warm-start
    restart test compares these files directly."""
    return json.dumps(_site_payload(sites), sort_keys=True,
                      separators=(",", ":")).encode()


def _persist_key(fn_label, in_tree, sig, policy, plan, hooked) -> str:
    """Content-address one transform-cache entry.

    Keyed the way jax's own ``compilation_cache`` keys executables: a
    hash over everything that determines the transform's output — the
    function identity (label), the input pytree structure and abstract
    signature, the full policy, the plan fingerprint, and the library
    versions — so an entry is reused exactly when re-tracing would have
    reproduced it.
    """
    payload = {
        "format": _PERSIST_FORMAT,
        "fn": fn_label,
        "in_tree": str(in_tree),
        "signature": [[list(shape), str(np.dtype(dt)), bool(weak)]
                      for shape, dt, weak in sig],
        "policy": dataclasses.asdict(policy),
        "plan": getattr(plan, "fingerprint", None),
        "hooked": bool(hooked),
        "jax": jax.__version__,
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:32]


class _DiskCache:
    """Fingerprinted on-disk transform cache (one dir, flat files).

    ``<key>.json`` holds the canonical site-decision bytes;
    ``<key>.bin`` holds the ``jax.export``-serialized program when the
    entry was exportable.  Writes are atomic (tmp + rename), corrupt or
    missing files degrade to a miss — never an error.
    """

    def __init__(self, directory):
        self.directory = str(directory)
        os.makedirs(self.directory, exist_ok=True)

    def _path(self, key: str, ext: str) -> str:
        return os.path.join(self.directory, f"{key}.{ext}")

    def load(self, key: str):
        """-> (raw decision bytes | None, deserialized Exported | None)."""
        try:
            with open(self._path(key, "json"), "rb") as f:
                raw = f.read()
        except OSError:
            return None, None
        exported = None
        if _jax_export is None:
            return raw, None
        try:
            with open(self._path(key, "bin"), "rb") as f:
                exported = _jax_export.deserialize(bytearray(f.read()))
        except OSError:
            pass
        except Exception as exc:  # corrupt/incompatible artifact
            warnings.warn(f"persisted transform program {key}.bin "
                          f"unusable ({exc!r}); re-tracing")
        return raw, exported

    def store(self, key: str, raw_json: bytes,
              exported_bytes: bytes | None) -> None:
        self._write(self._path(key, "json"), raw_json)
        if exported_bytes is not None:
            self._write(self._path(key, "bin"), exported_bytes)

    def _write(self, path: str, data: bytes) -> None:
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)


class _Entry:
    """One transform-cache entry; ``runnable`` is set for disk-restored
    exported programs and for ``jit_entries`` wrappers."""

    __slots__ = ("transformed", "sites", "out_tree", "runnable")

    def __init__(self, transformed, sites, out_tree, runnable=None):
        self.transformed = transformed
        self.sites = sites
        self.out_tree = out_tree
        self.runnable = runnable


def _entry_runner(transformed, out_tree):
    """A jit-compiled callable over the original (args, kwargs)
    signature that evaluates one transformed jaxpr."""

    def run(*args, **kwargs):
        flat, _ = jax.tree_util.tree_flatten((args, kwargs))
        out = jax.core.eval_jaxpr(transformed.jaxpr,
                                  transformed.consts, *flat)
        return jax.tree_util.tree_unflatten(out_tree, out)

    return jax.jit(run)


def _export_entry(transformed, out_tree, args, kwargs):
    """``jax.export``-serialize one entry's program, or None.

    Export legitimately fails for programs the serializer cannot carry
    (debug callbacks, unstable custom calls); the caller then persists
    decisions only.
    """

    def run(*a, **kw):
        flat, _ = jax.tree_util.tree_flatten((a, kw))
        out = jax.core.eval_jaxpr(transformed.jaxpr,
                                  transformed.consts, *flat)
        return jax.tree_util.tree_unflatten(out_tree, out)

    if _jax_export is None:  # pragma: no cover - very old jax
        return None
    try:
        exp = _jax_export.export(jax.jit(run))(*args, **kwargs)
        return exp.serialize()
    except Exception as exc:
        warnings.warn(f"transform entry not exportable ({exc!r}); "
                      "persisting decisions only")
        return None

#: Default bound on the per-wrapper transform cache.  Serve-style
#: callers present an open-ended stream of signatures (every padded
#: batch/prompt size is a new key), so the cache must evict, not grow.
OFFLOAD_CACHE_SIZE = 64


def offload(fn, policy: PrecisionPolicy | None = None, *,
            plan=None, plan_match: str = "strict",
            backend: GemmBackend | None = None,
            on_site_event=None,
            cache_size: int = OFFLOAD_CACHE_SIZE,
            persist_dir=None, fn_label: str | None = None,
            on_cache_event=None, jit_entries: bool = False):
    """Wrap ``fn`` so its large matmuls run through the policy backend.

    The first call for a given input signature traces ``fn`` once and
    transforms the jaxpr (see :func:`transform_jaxpr`); the transformed
    program is cached and later calls only evaluate it, so
    ``jax.jit(offload(fn, policy))`` compiles with no per-call
    re-tracing.  Batched/rank-N sites, sites inside ``scan``/``while``/
    ``cond``/``shard_map``/``pmap`` bodies, and reverse-mode AD are all
    supported; see the module docstring.

    ``plan`` accepts a :class:`repro.tune.PrecisionPlan`: when no
    explicit ``policy`` is given, the plan's policy
    (:meth:`PrecisionPolicy.from_plan`) drives the transform, and with
    ``plan_match="strict"`` every new signature's traced site set is
    validated against the plan's fingerprint
    (:meth:`~repro.tune.PrecisionPlan.validate_sites`) — a drifted
    program raises instead of silently running mis-tuned.
    ``plan_match="subset"`` skips the fingerprint check and just
    applies the overlapping per-site entries (the serve engine runs a
    train-calibrated plan this way).

    ``backend`` injects the default :class:`GemmBackend` instance
    instead of resolving ``policy.backend`` — the tuner's calibration
    pass rides the exact same wrapper/cache machinery this way, with
    its recording backend swapped in.

    ``on_site_event`` enables per-site execution telemetry: a host
    callable invoked (via ``jax.debug.callback``) with a static payload
    dict once per execution of each offloaded site — per ``scan``
    iteration, per mesh shard; see :func:`transform_jaxpr`.  Pass
    ``MetricsRun.site_event_handler()`` from :mod:`repro.obs` to count
    executions into a metrics run.  Note debug callbacks are
    asynchronous: call ``jax.effects_barrier()`` before reading
    anything the handler accumulates.

    The transform cache is a ``cache_size``-bounded LRU (least recently
    *used* signature evicted first), so signature churn — a serving
    loop padding every admission wave to a fresh (batch, prompt) shape
    — cannot retain unbounded transformed jaxprs.  Inspect it with
    ``wrapped.cache_info()`` and reset it with ``wrapped.cache_clear()``.

    The returned wrapper exposes ``wrapped.sites(*args, **kwargs)``,
    the exact :class:`Site` decisions taken for that signature — the
    same objects :func:`site_report` would produce, same names.

    ``persist_dir`` additionally persists the transform cache to disk,
    content-addressed the way jax's ``compilation_cache.py`` keys
    executables (function label + input signature + policy + plan
    fingerprint + library versions; see :func:`_persist_key`).  Each
    entry is two files: ``<key>.json``, the canonical byte encoding of
    the site decisions (two processes taking the same decisions write
    identical bytes), and ``<key>.bin``, the ``jax.export``-serialized
    program when exportable (it is not when ``on_site_event`` is set —
    debug callbacks cannot be serialized).  A restarted process that
    finds both files reuses the program without re-tracing or
    re-transforming; decisions-only entries are re-traced but
    byte-verified against the persisted decisions.  ``fn_label`` names
    the function in the key (defaults to ``fn.__name__`` — pass an
    explicit stable label, lambdas all share ``"<lambda>"``);
    ``on_cache_event`` is called with ``"miss"`` / ``"disk_hit"`` /
    ``"disk_decisions_hit"`` as entries resolve (in-memory hits are
    silent); ``wrapped.persist_info()`` returns the tallies.

    ``jit_entries=True`` gives every cache entry its own jit-compiled
    runner over the original call signature, so the wrapper is called
    *directly* instead of under an outer ``jax.jit`` — required when
    entries may come from disk as exported programs (which carry their
    own compilation) and fresh trace fallbacks must match.
    """
    if plan_match not in ("strict", "subset"):
        raise ValueError(f"plan_match must be 'strict' or 'subset', "
                         f"got {plan_match!r}")
    if policy is None:
        if plan is not None:
            # Subset mode exists for functions that trace a subset of
            # the calibrated sites (serving a train plan): the plan's
            # unmatched entries are expected there, not typos to warn
            # about.
            policy = PrecisionPolicy.from_plan(
                plan, **({"on_unmatched_site": "ignore"}
                         if plan_match == "subset" else {}))
        else:
            policy = PrecisionPolicy()
    backend = backend or get_backend(policy.backend, policy=policy)
    if cache_size < 1:
        raise ValueError(f"cache_size must be >= 1, got {cache_size}")
    cache: "OrderedDict[Any, _Entry]" = OrderedDict()
    stats = {"hits": 0, "misses": 0}
    pstats = {"disk_hits": 0, "disk_decisions_hits": 0,
              "disk_misses": 0}
    disk = _DiskCache(persist_dir) if persist_dir is not None else None
    label = fn_label or getattr(fn, "__name__", "fn")

    def _event(kind: str) -> None:
        if on_cache_event is not None:
            on_cache_event(kind)

    def build(args, kwargs):
        flat, in_tree = jax.tree_util.tree_flatten((args, kwargs))
        sig = _signature(flat)
        key = (in_tree, sig)
        entry = cache.get(key)
        if entry is not None:
            stats["hits"] += 1
            cache.move_to_end(key)
            return flat, entry

        raw = dkey = None
        if disk is not None:
            dkey = _persist_key(label, in_tree, sig, policy, plan,
                                on_site_event is not None)
            raw, exported = disk.load(dkey)
            if raw is not None:
                try:
                    restored = _sites_from_payload(json.loads(raw))
                except Exception as exc:
                    warnings.warn(f"persisted transform decisions "
                                  f"{dkey}.json unreadable ({exc!r}); "
                                  "re-tracing")
                    raw = None
                else:
                    if exported is not None:
                        # Full warm start: restored program, zero
                        # tracing/transform work in this process.
                        pstats["disk_hits"] += 1
                        _event("disk_hit")
                        entry = _Entry(None, restored, None,
                                       jax.jit(exported.call))
                        cache[key] = entry
                        while len(cache) > cache_size:
                            cache.popitem(last=False)
                        return flat, entry

        stats["misses"] += 1
        closed, out_shape = jax.make_jaxpr(
            fn, return_shape=True)(*args, **kwargs)
        transformed, sites = transform_jaxpr(
            closed, policy, backend, on_site_event=on_site_event)
        if plan is not None and plan_match == "strict":
            plan.validate_sites(sites)
        out_tree = jax.tree_util.tree_structure(out_shape)
        entry = _Entry(transformed, sites, out_tree)
        if jit_entries:
            entry.runnable = _entry_runner(transformed, out_tree)
        if disk is not None:
            fresh = _sites_bytes(sites)
            if raw is not None:
                # Decisions were on disk (no runnable program): the
                # re-trace must reproduce them byte-for-byte, or the
                # environment changed under a colliding key.
                if fresh != raw:
                    warnings.warn(
                        f"persisted transform decisions {dkey}.json "
                        "do not match this process's re-trace; "
                        "overwriting with the fresh decisions")
                    disk.store(dkey, fresh, None)
                pstats["disk_decisions_hits"] += 1
                _event("disk_decisions_hit")
            else:
                pstats["disk_misses"] += 1
                _event("miss")
                exported_bytes = None
                if on_site_event is None:
                    exported_bytes = _export_entry(transformed,
                                                   out_tree, args,
                                                   kwargs)
                disk.store(dkey, fresh, exported_bytes)
        cache[key] = entry
        while len(cache) > cache_size:
            cache.popitem(last=False)
        return flat, entry

    def wrapped(*args, **kwargs):
        flat, entry = build(args, kwargs)
        if entry.runnable is not None:
            return entry.runnable(*args, **kwargs)
        out_flat = jax.core.eval_jaxpr(entry.transformed.jaxpr,
                                       entry.transformed.consts, *flat)
        return jax.tree_util.tree_unflatten(entry.out_tree, out_flat)

    def sites(*args, **kwargs) -> List[Site]:
        _, entry = build(args, kwargs)
        return entry.sites

    def cache_info() -> CacheInfo:
        return CacheInfo(stats["hits"], stats["misses"], cache_size,
                         len(cache))

    def persist_info() -> PersistInfo:
        return PersistInfo(pstats["disk_hits"],
                           pstats["disk_decisions_hits"],
                           pstats["disk_misses"],
                           disk.directory if disk else None)

    def cache_clear() -> None:
        cache.clear()
        stats["hits"] = stats["misses"] = 0

    wrapped.__name__ = f"offload({getattr(fn, '__name__', 'fn')})"
    wrapped.sites = sites
    wrapped.policy = policy
    wrapped.backend = backend
    wrapped.cache_info = cache_info
    wrapped.persist_info = persist_info
    wrapped.cache_clear = cache_clear
    return wrapped


def site_report(fn, policy: PrecisionPolicy | None = None):
    """Enumerate the BLAS-3 sites ``offload`` would rewrite in ``fn``.

    Returns a function with the same signature as ``fn`` that returns a
    list of :class:`Site` records instead of computing.  The names are
    the same structural names :func:`offload` uses (one shared walker),
    so they are valid ``PrecisionPolicy.site_splits`` keys.
    """
    policy = policy or PrecisionPolicy()

    def reporter(*args, **kwargs) -> List[Site]:
        closed = jax.make_jaxpr(fn)(*args, **kwargs)
        return [_classify(eqn, policy, name, mult, spmd)
                for eqn, name, mult, spmd in _walk_sites(closed.jaxpr)]

    reporter.__name__ = f"site_report({getattr(fn, '__name__', 'fn')})"
    return reporter
