"""Automatic BLAS offload: rewrite ``dot_general`` sites in any JAX fn.

The paper intercepts BLAS calls of an *unmodified* application at the
linker level and redirects large GEMMs to the INT8 emulation engine.
The JAX analogue is a jaxpr interpreter: trace the user function,
walk the resulting jaxpr, and re-emit every qualifying ``dot_general``
through :func:`repro.core.ozaki.ozaki_matmul` while binding every other
primitive unchanged.  The user function is never edited — this is the
"automatic offloading" axis of the paper's title.

Public API
----------

``offload(fn, policy)``
    Returns a drop-in replacement for ``fn`` whose large matmuls run
    emulated.  Composable with ``jax.jit``.

``site_report(fn, policy)``
    Returns a function that, instead of computing, lists the BLAS-3
    sites the interceptor would touch (name, shapes, dtype, decision)
    — the PEAK-profiler "enumerate first, then offload" workflow.
"""

from __future__ import annotations

from typing import Any, List, Sequence

import jax
import jax.numpy as jnp

try:  # jax >= 0.4.35 exposes the jaxpr IR under jax.extend.core
    from jax.extend import core as jex_core
except ImportError:  # pragma: no cover - older jax
    from jax import core as jex_core

from .ozaki import ozaki_matmul
from .precision import PrecisionPolicy

__all__ = ["offload", "site_report", "Site"]

# Higher-order primitives whose body jaxpr we descend into so nested
# dot_generals are rewritten too.  (Control-flow primitives — scan,
# while, cond — are bound natively for now; their bodies re-enter the
# interceptor only if the user offloads them separately.)
_CALL_PRIMITIVES = {"pjit", "closed_call", "custom_jvp_call",
                    "custom_vjp_call", "remat", "checkpoint"}


class Site:
    """One discovered ``dot_general`` site."""

    def __init__(self, name: str, lhs_shape, rhs_shape, dtype,
                 offloaded: bool, splits: int, reason: str):
        self.name = name
        self.lhs_shape = tuple(lhs_shape)
        self.rhs_shape = tuple(rhs_shape)
        self.dtype = jnp.dtype(dtype)
        self.offloaded = offloaded
        self.splits = splits
        self.reason = reason

    def __repr__(self):
        action = (f"offload fp64_int8_{self.splits}" if self.offloaded
                  else f"native ({self.reason})")
        return (f"{self.name}: {self.lhs_shape} @ {self.rhs_shape} "
                f"{self.dtype.name} -> {action}")


def _classify(eqn, policy: PrecisionPolicy, name: str) -> Site:
    """Decide whether one dot_general equation gets offloaded."""
    lhs_aval, rhs_aval = (v.aval for v in eqn.invars)
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    dtype = eqn.outvars[0].aval.dtype

    def skip(reason):
        return Site(name, lhs_aval.shape, rhs_aval.shape, dtype,
                    False, 0, reason)

    if lb or rb:
        return skip("batched")
    if lhs_aval.ndim != 2 or rhs_aval.ndim != 2:
        return skip(f"rank {lhs_aval.ndim}x{rhs_aval.ndim}")
    if len(lc) != 1 or len(rc) != 1:
        return skip("multi-dim contraction")
    if not (jnp.issubdtype(dtype, jnp.floating)
            or jnp.issubdtype(dtype, jnp.complexfloating)):
        return skip(f"dtype {jnp.dtype(dtype).name}")
    m = lhs_aval.shape[1 - lc[0]]
    k = lhs_aval.shape[lc[0]]
    n = rhs_aval.shape[1 - rc[0]]
    if min(m, k, n) < policy.min_dim:
        return skip(f"min(m,k,n)={min(m, k, n)} < min_dim={policy.min_dim}")
    return Site(name, lhs_aval.shape, rhs_aval.shape, dtype,
                True, policy.splits_for(name), "")


def _emulated_dot(lhs, rhs, eqn, site: Site, policy: PrecisionPolicy):
    """Re-emit a qualifying dot_general through the Ozaki engine."""
    (lc, rc), _ = eqn.params["dimension_numbers"]
    # Normalize to (m, k) @ (k, n): move the contraction axes inward.
    if lc[0] != 1:
        lhs = jnp.swapaxes(lhs, 0, 1)
    if rc[0] != 0:
        rhs = jnp.swapaxes(rhs, 0, 1)
    out = ozaki_matmul(lhs, rhs, num_splits=site.splits,
                       accumulator=policy.accumulator,
                       out_dtype=eqn.outvars[0].aval.dtype,
                       slice_bits=policy.slice_bits)
    return out


def _subjaxprs(eqn):
    """Yield (jaxpr, consts) for the body of a call-like equation."""
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        sub = eqn.params.get(key)
        if sub is None:
            continue
        if hasattr(sub, "jaxpr"):  # ClosedJaxpr
            yield sub.jaxpr, sub.consts
        else:
            yield sub, []
        return


def _walk_sites(jaxpr, policy: PrecisionPolicy, sites: List[Site],
                prefix: str) -> None:
    """Collect dot_general sites without executing anything."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "dot_general":
            sites.append(_classify(eqn, policy,
                                   f"{prefix}dot{len(sites)}"))
        elif eqn.primitive.name in _CALL_PRIMITIVES:
            for sub, _ in _subjaxprs(eqn):
                _walk_sites(sub, policy, sites, prefix)


def _eval_jaxpr(jaxpr, consts: Sequence[Any], args: Sequence[Any],
                policy: PrecisionPolicy, counter: List[int]):
    """Interpret a jaxpr, swapping qualifying dot_generals for emulation."""
    env = {}

    def read(v):
        return v.val if isinstance(v, jex_core.Literal) else env[v]

    def write(v, val):
        env[v] = val

    for var, const in zip(jaxpr.constvars, consts):
        write(var, const)
    for var, arg in zip(jaxpr.invars, args):
        write(var, arg)

    for eqn in jaxpr.eqns:
        invals = [read(v) for v in eqn.invars]
        name = eqn.primitive.name
        if name == "dot_general":
            site = _classify(eqn, policy, f"dot{counter[0]}")
            counter[0] += 1
            if site.offloaded:
                outvals = [_emulated_dot(invals[0], invals[1], eqn,
                                         site, policy)]
            else:
                outvals = [eqn.primitive.bind(*invals, **eqn.params)]
        elif name in _CALL_PRIMITIVES:
            handled = False
            for sub, sub_consts in _subjaxprs(eqn):
                outvals = _eval_jaxpr(sub, sub_consts, invals, policy,
                                      counter)
                handled = True
            if not handled:  # no body found — bind natively
                outvals = eqn.primitive.bind(*invals, **eqn.params)
                if not eqn.primitive.multiple_results:
                    outvals = [outvals]
        else:
            outvals = eqn.primitive.bind(*invals, **eqn.params)
            if not eqn.primitive.multiple_results:
                outvals = [outvals]
        for var, val in zip(eqn.outvars, outvals):
            write(var, val)

    return [read(v) for v in jaxpr.outvars]


def offload(fn, policy: PrecisionPolicy | None = None):
    """Wrap ``fn`` so its large matmuls run INT8-emulated.

    ``fn`` is traced with ``jax.make_jaxpr`` on each call (cheap, and
    cached by XLA once jitted); every ``dot_general`` whose operand
    dimensions all reach ``policy.min_dim`` is rewritten through
    :func:`ozaki_matmul` with the policy's split count.  All other
    primitives — including ones inside nested ``pjit``/``custom_jvp``
    bodies — execute unchanged.

    The wrapper is itself traceable: ``jax.jit(offload(fn, policy))``
    compiles the rewritten computation.
    """
    policy = policy or PrecisionPolicy()

    def wrapped(*args, **kwargs):
        closed, out_shape = jax.make_jaxpr(
            fn, return_shape=True)(*args, **kwargs)
        flat_args = jax.tree_util.tree_leaves((args, kwargs))
        flat_out = _eval_jaxpr(closed.jaxpr, closed.consts, flat_args,
                               policy, counter=[0])
        out_tree = jax.tree_util.tree_structure(out_shape)
        return jax.tree_util.tree_unflatten(out_tree, flat_out)

    wrapped.__name__ = f"offload({getattr(fn, '__name__', 'fn')})"
    return wrapped


def site_report(fn, policy: PrecisionPolicy | None = None):
    """Enumerate the BLAS-3 sites ``offload`` would rewrite in ``fn``.

    Returns a function with the same signature as ``fn`` that returns a
    list of :class:`Site` records instead of computing.
    """
    policy = policy or PrecisionPolicy()

    def reporter(*args, **kwargs) -> List[Site]:
        closed = jax.make_jaxpr(fn)(*args, **kwargs)
        sites: List[Site] = []
        _walk_sites(closed.jaxpr, policy, sites, prefix="")
        return sites

    reporter.__name__ = f"site_report({getattr(fn, '__name__', 'fn')})"
    return reporter
