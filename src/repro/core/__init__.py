"""repro.core — tunable-precision INT8 GEMM emulation.

Layers:
  * :mod:`repro.core.ozaki`      — the split-GEMM arithmetic engine;
  * :mod:`repro.core.precision`  — the accuracy knob (policies, split
    prediction/measurement, adaptive per-site tuning);
  * :mod:`repro.core.intercept`  — automatic BLAS offload for
    unmodified JAX functions.
"""

from .intercept import Site, offload, site_report
from .ozaki import (SLICE_BITS, num_pair_gemms, ozaki_matmul,
                    pair_indices, slice_matrix)
from .precision import (AdaptiveGemm, PrecisionPolicy, SiteState,
                        estimate_rel_error, measure_splits,
                        predict_splits)

__all__ = [
    "SLICE_BITS",
    "AdaptiveGemm",
    "PrecisionPolicy",
    "Site",
    "SiteState",
    "estimate_rel_error",
    "measure_splits",
    "num_pair_gemms",
    "offload",
    "ozaki_matmul",
    "pair_indices",
    "predict_splits",
    "site_report",
    "slice_matrix",
]
