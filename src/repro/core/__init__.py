"""repro.core — tunable-precision INT8 GEMM emulation.

Layers:
  * :mod:`repro.core.ozaki`      — the split-GEMM arithmetic engine;
  * :mod:`repro.core.precision`  — the accuracy knob (policies, split
    prediction/measurement, adaptive per-site tuning);
  * :mod:`repro.core.backends`   — the GEMM backend registry, where a
    policy binds to an execution engine (spec strings);
  * :mod:`repro.core.intercept`  — automatic BLAS offload: the
    jaxpr->jaxpr transform for unmodified JAX functions.
"""

from .backends import (GemmBackend, example_specs, get_backend,
                       register_backend, registered_families)
from .intercept import (CacheInfo, PersistInfo, Site, offload,
                        site_report, transform_jaxpr)
from .ozaki import (SLICE_BITS, num_pair_gemms, ozaki_matmul,
                    pair_indices, slice_matrix)
from .precision import (AdaptiveGemm, PrecisionPolicy, SiteState,
                        canonical_site, estimate_rel_error,
                        measure_splits, predict_splits,
                        splits_for_tolerance)

__all__ = [
    "SLICE_BITS",
    "AdaptiveGemm",
    "CacheInfo",
    "canonical_site",
    "GemmBackend",
    "PrecisionPolicy",
    "Site",
    "SiteState",
    "estimate_rel_error",
    "example_specs",
    "get_backend",
    "measure_splits",
    "num_pair_gemms",
    "offload",
    "ozaki_matmul",
    "pair_indices",
    "PersistInfo",
    "predict_splits",
    "register_backend",
    "registered_families",
    "site_report",
    "slice_matrix",
    "splits_for_tolerance",
    "transform_jaxpr",
]
