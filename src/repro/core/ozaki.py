"""Ozaki-scheme INT8 split-GEMM emulation of high-precision matmuls.

The Ozaki scheme writes a floating-point matrix as an exact sum of
narrow integer "slices"

    A / sigma_A  =  sum_t  S_t * 2**(-w*(t+1)),      S_t in int8,

where ``sigma_A`` is a per-row power-of-two scale and ``w`` is the slice
width in bits.  Products of slices are then exact in INT8xINT8->INT32
arithmetic (the datatype tensor cores / the TPU MXU natively consume),
and the high-precision product is recovered by accumulating the pair
products ``S_i(A) @ S_j(B)`` with the appropriate power-of-two weights.

With ``s`` slices per operand we follow the standard truncated scheme
and keep only the pairs with ``i + j < s`` — ``s*(s+1)/2`` GEMMs — so
the split count tunes accuracy continuously: each extra split buys
roughly ``w`` more mantissa bits.

Two accumulators are provided:

* ``"f64"``   — accumulate the scaled INT32 pair products in float64
  (what ozIMMU does on CUDA hardware with FP64 units);
* ``"df32"``  — "double-float32": every INT32 pair product is split
  exactly into a hi/lo pair of float32 values and the weighted sum is
  carried with compensated (TwoSum) float32 arithmetic, giving ~48
  effective mantissa bits without touching an FP64 unit.  This is the
  accumulator of interest for FP64-free accelerators (TPU v5e).

Complex inputs are handled by four real split-GEMMs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SLICE_BITS",
    "complex_matmul_via_real",
    "num_pair_gemms",
    "pair_indices",
    "slice_matrix",
    "ozaki_matmul",
]

# Bits of mantissa carried per int8 slice.  Slice values live in
# [-2**(SLICE_BITS-1), 2**(SLICE_BITS-1)] so an int8 comfortably holds
# them and k-long INT32 dot products cannot overflow for any practical
# k (|q_a*q_b| <= 2**(2w-2); k < 2**(33-2w)).  Six bits per slice keeps
# the s=3..9 accuracy ladder strictly monotone before hitting the f64
# reference floor, mirroring the paper's Table 1 trend.
SLICE_BITS = 6


def num_pair_gemms(num_splits: int) -> int:
    """Number of INT8 GEMMs issued for a given split count."""
    return num_splits * (num_splits + 1) // 2


def pair_indices(num_splits: int) -> tuple[np.ndarray, np.ndarray]:
    """Slice-index pairs (i, j) with i + j < num_splits, by ascending i+j.

    Ordering by total shift means the compensated accumulation adds
    terms from largest to smallest magnitude.
    """
    pairs = [(i, j) for i in range(num_splits) for j in range(num_splits)
             if i + j < num_splits]
    pairs.sort(key=lambda ij: (ij[0] + ij[1], ij[0]))
    ii = np.array([p[0] for p in pairs], dtype=np.int32)
    jj = np.array([p[1] for p in pairs], dtype=np.int32)
    return ii, jj


def _pow2_scale(x: jax.Array, axis: int) -> jax.Array:
    """Per-row/col power-of-two scale sigma with |x| / sigma <= 1/2."""
    absmax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    # exponent e with 2**e >= 2*absmax; zero rows get sigma = 1.
    # NB: jnp.exp2 is approximate on some backends, ldexp is exact.
    e = jnp.where(absmax > 0, jnp.ceil(jnp.log2(absmax)) + 1.0, 0.0)
    return jnp.ldexp(jnp.ones_like(absmax), e.astype(jnp.int32))


def slice_matrix(x: jax.Array, num_splits: int, axis: int,
                 slice_bits: int = SLICE_BITS):
    """Split ``x`` into int8 slices along its value (mantissa) axis.

    Returns ``(slices, sigma)`` with ``slices`` of shape
    ``(num_splits, *x.shape)`` (int8) and ``sigma`` the per-row (axis=1)
    or per-column (axis=0) power-of-two scale, such that

        x ~= sigma * sum_t slices[t] * 2**(-slice_bits*(t+1)).

    The remainder after ``num_splits`` slices is < 2**(-w*s - 1) per
    element (relative to sigma): the splitting itself is exact in f64
    arithmetic, only the truncation to ``num_splits`` slices loses bits.
    """
    compute_dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    x = x.astype(compute_dtype)
    sigma = _pow2_scale(x, axis=axis)
    r = x / sigma  # |r| <= 0.5, scaling by a power of two is exact
    radix = float(2 ** slice_bits)
    out = []
    for _ in range(num_splits):
        q = jnp.round(r * radix)  # |q| <= 2**(slice_bits-1) after step 1
        out.append(q.astype(jnp.int8))
        r = r * radix - q  # exact: both operands share an exponent window
    return jnp.stack(out), jnp.squeeze(sigma, axis=axis)


def _int8_pair_products(a_sl, b_sl, ii, jj):
    """Batched INT8 GEMMs over the selected slice pairs -> int32 (p,m,n)."""
    a_p = jnp.take(a_sl, jnp.asarray(ii), axis=0)  # (p, m, k) int8
    b_p = jnp.take(b_sl, jnp.asarray(jj), axis=0)  # (p, k, n) int8
    return jax.lax.dot_general(
        a_p, b_p,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32)


def _accumulate_f64(prod, shifts, slice_bits):
    """Weighted float64 accumulation of the INT32 pair products."""
    # shifts is a static numpy array: build exact power-of-two weights
    # host-side (jnp.exp2 is NOT exact for integer args on XLA CPU).
    w = np.ldexp(1.0, -(np.asarray(shifts) + 2) * slice_bits)
    return jnp.einsum("p,pmn->mn", jnp.asarray(w, jnp.float64),
                      prod.astype(jnp.float64))


def _two_sum(acc, term):
    """Knuth TwoSum: acc + term = s + err exactly (any float dtype)."""
    s = acc + term
    bp = s - acc
    err = (acc - (s - bp)) + (term - bp)
    return s, err


def _accumulate_df32(prod, shifts, slice_bits, num_splits):
    """Compensated double-float32 accumulation.

    Each INT32 pair product is split exactly into hi/lo float32 parts,
    weighted by a *non-negative* power-of-two shift (so the weighting is
    exact in f32 and never underflows), and folded into a compensated
    (sum, err) float32 pair.  The caller divides by the deferred scale
    2**(w*(s+1)) at combine time.
    """
    smax = num_splits - 1
    hi = prod.astype(jnp.float32)
    # hi is integral and |prod| stays far below 2**31 for practical
    # k/slice_bits, so casting back to the int32 input dtype is exact —
    # and unlike int64 it does not warn when jax_enable_x64 is off
    # (the LM examples train in pure float32 without x64).
    lo = (prod - hi.astype(prod.dtype)).astype(jnp.float32)
    # Positive shifts: pair (i, j) gets weight 2**(w*(smax - i - j)).
    # Exact host-side powers of two (jnp.exp2 is approximate on CPU).
    w = np.ldexp(np.float32(1.0), (smax - np.asarray(shifts)) * slice_bits)
    w = jnp.asarray(w, jnp.float32)[:, None, None]
    t_hi = hi * w  # exact: power-of-two weight, well inside f32 range
    t_lo = lo * w
    acc = jnp.zeros(prod.shape[1:], jnp.float32)
    comp = jnp.zeros(prod.shape[1:], jnp.float32)
    for p in range(prod.shape[0]):  # pairs ordered large -> small
        acc, err = _two_sum(acc, t_hi[p])
        comp = comp + err
        acc, err = _two_sum(acc, t_lo[p])
        comp = comp + err
    deferred = 2.0 ** (-slice_bits * (smax + 2))
    return acc, comp, deferred


@functools.partial(jax.jit, static_argnames=("num_splits", "accumulator",
                                             "out_dtype", "slice_bits"))
def _real_ozaki(a, b, num_splits, accumulator, out_dtype, slice_bits):
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"shape mismatch {a.shape} @ {b.shape}")
    a_sl, sigma_a = slice_matrix(a, num_splits, axis=1,
                                 slice_bits=slice_bits)
    b_sl, sigma_b = slice_matrix(b, num_splits, axis=0,
                                 slice_bits=slice_bits)
    ii, jj = pair_indices(num_splits)
    prod = _int8_pair_products(a_sl, b_sl, ii, jj)
    shifts = ii + jj
    if accumulator == "f64":
        c = _accumulate_f64(prod, shifts, slice_bits)
        c = c.astype(out_dtype)
    elif accumulator == "df32":
        acc, comp, deferred = _accumulate_df32(prod, shifts, slice_bits,
                                               num_splits)
        c = (acc.astype(out_dtype) + comp.astype(out_dtype)) * deferred
    else:
        raise ValueError(f"unknown accumulator {accumulator!r};"
                         " expected 'df32' or 'f64'")
    scale = (sigma_a[:, None] * sigma_b[None, :]).astype(out_dtype)
    return c * scale


def complex_matmul_via_real(real_matmul, a, b, out_dtype):
    """Complex product from four real GEMMs — shared by every engine.

    ``real_matmul(x, y, real_out_dtype)`` runs one real matmul; the
    decomposition, the real working dtype (f64 for complex128, f32
    otherwise) and the final cast live here so the jnp and Pallas
    paths cannot drift apart.
    """
    out_dtype = jnp.dtype(out_dtype)
    real_out = jnp.float64 if out_dtype in (jnp.complex128, jnp.float64) \
        else jnp.float32
    ar, ai = jnp.real(a), jnp.imag(a)
    br, bi = jnp.real(b), jnp.imag(b)
    cr = real_matmul(ar, br, real_out) - real_matmul(ai, bi, real_out)
    ci = real_matmul(ar, bi, real_out) + real_matmul(ai, br, real_out)
    return jax.lax.complex(cr, ci).astype(out_dtype)


def ozaki_matmul(a, b, num_splits: int = 6, accumulator: str = "df32",
                 out_dtype=None, slice_bits: int = SLICE_BITS):
    """Emulated high-precision matmul ``a @ b`` via INT8 split GEMMs.

    Args:
      a: (m, k) real or complex floating array.
      b: (k, n) real or complex floating array.
      num_splits: slice count ``s``; issues ``s*(s+1)/2`` INT8 GEMMs and
        carries roughly ``slice_bits * s`` mantissa bits.
      accumulator: ``"df32"`` (compensated float32 pairs, FP64-free) or
        ``"f64"`` (plain float64 accumulation).
      out_dtype: result dtype; defaults to the common input dtype.
      slice_bits: mantissa bits per int8 slice.

    Returns:
      (m, n) array of ``out_dtype``.
    """
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError("ozaki_matmul expects 2-D operands, got "
                         f"{a.shape} @ {b.shape}")
    if num_splits < 1:
        raise ValueError(f"num_splits must be >= 1, got {num_splits}")
    if out_dtype is None:
        out_dtype = jnp.result_type(a.dtype, b.dtype)
    out_dtype = jnp.dtype(out_dtype)

    if jnp.issubdtype(a.dtype, jnp.complexfloating) or \
       jnp.issubdtype(b.dtype, jnp.complexfloating) or \
       jnp.issubdtype(out_dtype, jnp.complexfloating):
        def part(x, y, real_out):
            return _real_ozaki(x, y, num_splits=num_splits,
                               accumulator=accumulator,
                               out_dtype=real_out,
                               slice_bits=slice_bits)

        return complex_matmul_via_real(part, a, b, out_dtype)

    return _real_ozaki(a, b, num_splits, accumulator, out_dtype,
                       slice_bits)
