"""Tunable-precision policy layer: pick the split count for a tolerance.

The paper's central observation is that emulation accuracy is a *knob*:
the split count trades INT8 GEMM volume (``s*(s+1)/2`` products) for
mantissa bits (roughly ``SLICE_BITS * s``).  This module provides the
three ways to turn that knob:

* :func:`predict_splits`   — a priori, from the error model;
* :func:`measure_splits`   — empirically, by probing the actual operands;
* :class:`AdaptiveGemm`    — stateful per-call-site tuning, the
  "adaptive precision strategies" the paper advocates for.

plus :class:`PrecisionPolicy`, the configuration record consumed by the
automatic-offload interceptor (:mod:`repro.core.intercept`).
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .ozaki import SLICE_BITS, ozaki_matmul

__all__ = [
    "PrecisionPolicy",
    "SiteState",
    "AdaptiveGemm",
    "canonical_site",
    "predict_splits",
    "splits_for_tolerance",
    "measure_splits",
    "estimate_rel_error",
]

#: Hard ceiling on the split count: beyond this the slices cover more
#: mantissa than an f64 input carries and extra splits cannot help.
MAX_SPLITS = 14

# SPMD scope components of a structural site name ("shmap0/", "pmap1/").
_SPMD_SCOPE_RE = re.compile(r"(shmap|pmap)\d+")


def canonical_site(name: str) -> str:
    """Strip SPMD scopes from a structural site name.

    ``"shmap0/scan0/dot1" -> "scan0/dot1"``.  A data-parallel
    ``shard_map`` wraps the *same* program body that runs on a single
    device, so per-site tuning decisions (split counts, backend
    overrides, persisted precision plans) are keyed by the canonical
    name: a plan calibrated under a mesh applies to the single-device
    program and vice versa.  Control-flow scopes (``scan0/``,
    ``cond1/br0/``) are part of the program structure and stay.
    """
    return "/".join(p for p in name.split("/")
                    if not _SPMD_SCOPE_RE.fullmatch(p))


@dataclasses.dataclass
class PrecisionPolicy:
    """How the interceptor treats discovered BLAS-3 sites.

    Attributes:
      default_splits: split count for sites without an override.
      min_dim: only offload a ``dot_general`` whose m, k and n are all
        at least this large (batch dimensions do not count; for rank-N
        contractions m/k/n are the merged free/contraction extents);
        smaller GEMMs stay native (emulation overhead cannot amortize,
        mirroring the paper's size cutoff in the offloading tool).
      accumulator: ``"df32"`` or ``"f64"`` (see
        :func:`repro.core.ozaki.ozaki_matmul`).
      slice_bits: mantissa bits per int8 slice.
      backend: spec string (see :mod:`repro.core.backends`) naming the
        engine that offloaded sites execute on.  Leave the family
        unpinned (``"fp64_int8"``, not ``"fp64_int8_6"``) so
        ``default_splits``/``site_splits`` stay in charge of precision;
        a pinned spec is authoritative and bypasses both.
      site_splits: per-site split-count overrides, keyed by the stable
        structural site names that :func:`repro.core.intercept.site_report`
        and :func:`repro.core.intercept.offload` share (e.g. ``"dot1"``,
        ``"scan0/dot0"``).  Keys may be canonical (SPMD scopes
        stripped): a ``"scan0/dot0"`` override also applies to the
        ``"shmap0/scan0/dot0"`` site of the same program run
        data-parallel.
      site_backends: per-site backend-spec overrides, same keys.  A
        site mapped to ``"dgemm"`` is *demoted*: it runs native even
        though it passes the size gate (how a precision plan disables
        emulation for a pathological operator).
      on_unmatched_site: what the offload transform does with a
        ``site_splits``/``site_backends`` key that matches no site in
        the traced function — ``"warn"`` (default; typo'd site names
        should not silently run at default splits), ``"raise"``
        (strict mode), or ``"ignore"`` (for plans applied to a
        function that intentionally covers a site subset, e.g. a
        train-calibrated plan driving the serve engine).
    """

    default_splits: int = 6
    min_dim: int = 128
    accumulator: str = "df32"
    slice_bits: int = SLICE_BITS
    backend: str = "fp64_int8"
    site_splits: Dict[str, int] = dataclasses.field(default_factory=dict)
    site_backends: Dict[str, str] = dataclasses.field(default_factory=dict)
    on_unmatched_site: str = "warn"

    def _lookup(self, table: Dict[str, object], site: str):
        if site in table:
            return table[site]
        canon = canonical_site(site)
        if canon in table:
            return table[canon]
        # Keys copied from a *sharded* site_report ("shmap0/scan0/dot0")
        # must also reach the unsharded program's "scan0/dot0" site:
        # match on the keys' canonical forms too (tables are small).
        for key, val in table.items():
            if canonical_site(key) == canon:
                return val
        return None

    def splits_for(self, site: str) -> int:
        got = self._lookup(self.site_splits, site)
        return self.default_splits if got is None else got

    def backend_for(self, site: str) -> str:
        """The backend spec an offloaded ``site`` executes on."""
        got = self._lookup(self.site_backends, site)
        return self.backend if got is None else got

    def unmatched_overrides(self, known_sites) -> list:
        """Override keys that match none of ``known_sites``.

        A key matches a site exactly, or canonically (the key is the
        SPMD-stripped form of a site name).  The offload transform
        calls this with the walked site-name set and warns/raises per
        ``on_unmatched_site``.
        """
        known = set(known_sites)
        known |= {canonical_site(n) for n in known}
        return sorted(k for k in {*self.site_splits, *self.site_backends}
                      if k not in known and canonical_site(k) not in known)

    @classmethod
    def from_plan(cls, plan, **overrides) -> "PrecisionPolicy":
        """Build the policy a :class:`~repro.tune.PrecisionPlan` encodes.

        The plan is the complete precision configuration: backend
        family, accumulator, slice bits, size gate, per-site split
        counts, and per-site demotions to ``"dgemm"``.  ``overrides``
        replace individual fields (e.g. ``on_unmatched_site="ignore"``
        when the plan is applied to a function that covers a subset of
        the calibrated sites).
        """
        site_splits = {s.site: s.splits for s in plan.sites
                       if s.backend != "dgemm"}
        site_backends = {s.site: s.backend for s in plan.sites
                         if s.backend != plan.backend}
        kw = dict(
            default_splits=max(site_splits.values(), default=6),
            min_dim=plan.min_dim,
            accumulator=plan.accumulator,
            slice_bits=plan.slice_bits,
            backend=plan.backend,
            site_splits=site_splits,
            site_backends=site_backends,
        )
        kw.update(overrides)
        return cls(**kw)


def estimate_rel_error(num_splits: int, k: int,
                       slice_bits: int = SLICE_BITS) -> float:
    """A-priori bound on max |C_emul - C| / (|A| @ |B|).

    After ``s`` slices the per-element truncation of the scaled operand
    is below ``2**(-w*s)``; the dropped cross terms (i + j >= s) are of
    the same order, and the k-fold accumulation contributes a modest
    O(sqrt(k)) growth for zero-mean data.  The constant is calibrated
    against the Gaussian sweeps in the quickstart (it intentionally
    over-estimates: predict_splits should err toward accuracy).
    """
    return 4.0 * math.sqrt(k) * 2.0 ** (-slice_bits * num_splits)


def splits_for_tolerance(target_rel: float, k: int,
                         slice_bits: int = SLICE_BITS) -> int:
    """Smallest split count whose modeled error meets ``target_rel``.

    Shape-only version of :func:`predict_splits`: usable inside traces
    (``jit``/``vmap``/the offload transform) where operand *values* are
    abstract but the contraction extent ``k`` is static.
    """
    for s in range(1, MAX_SPLITS + 1):
        if estimate_rel_error(s, k, slice_bits) <= target_rel:
            return s
    return MAX_SPLITS


def predict_splits(a, b=None, target_rel: float = 1e-9,
                   slice_bits: int = SLICE_BITS) -> int:
    """Smallest split count whose modeled error meets ``target_rel``.

    The bound only depends on the operands through the shared
    contraction extent ``K`` (the error model
    :func:`estimate_rel_error` is ``4 sqrt(K) 2**(-w s)``): ``K`` is
    read off both operands — ``a``'s last axis and ``b``'s
    second-to-last (matmul convention) — and a mismatch raises rather
    than silently modeling the wrong accumulation length.  ``b`` may be
    omitted (deprecation shim for the historical two-operand
    signature), in which case ``a`` alone fixes ``K``.
    """
    k = int(a.shape[-1])
    if b is not None:
        kb = int(b.shape[-2]) if b.ndim >= 2 else int(b.shape[-1])
        if kb != k:
            raise ValueError(
                f"contraction extents disagree: a has K={k} (shape "
                f"{tuple(a.shape)}), b has K={kb} (shape "
                f"{tuple(b.shape)})")
    return splits_for_tolerance(target_rel, k, slice_bits)


def measure_splits(a, b, target_rel: float, accumulator: str = "df32",
                   slice_bits: int = SLICE_BITS,
                   start: Optional[int] = None):
    """Empirical split selection against the actual operands.

    Runs the emulated GEMM with increasing split counts until its max
    relative error (vs. the native high-precision product, normalized
    by ``|A| @ |B|``) meets ``target_rel``.

    Returns:
      ``(num_splits, achieved_rel_error)``.
    """
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    # Probe in the widest available precision regardless of the input
    # dtype: a float32 reference would floor the measurable error at
    # ~1e-6 and make tighter targets silently unreachable.
    ref_dtype = (jnp.complex128 if jnp.iscomplexobj(a)
                 or jnp.iscomplexobj(b) else jnp.float64)
    if not jax.config.jax_enable_x64:
        ref_dtype = jnp.complex64 if ref_dtype == jnp.complex128 \
            else jnp.float32
    ref = jnp.matmul(a.astype(ref_dtype), b.astype(ref_dtype))
    denom = jnp.abs(jnp.matmul(jnp.abs(a).astype(ref_dtype),
                               jnp.abs(b).astype(ref_dtype)))
    denom = jnp.where(denom == 0, 1.0, denom)
    s0 = start if start is not None else max(
        1, predict_splits(a, b, target_rel, slice_bits) - 2)
    err = float("inf")
    for s in range(s0, MAX_SPLITS + 1):
        c = ozaki_matmul(a, b, num_splits=s, accumulator=accumulator,
                         out_dtype=ref_dtype, slice_bits=slice_bits)
        err = float(jnp.max(jnp.abs(c - ref) / denom))
        if err <= target_rel:
            return s, err
    return MAX_SPLITS, err


@dataclasses.dataclass
class SiteState:
    """Per-call-site tuning record kept by :class:`AdaptiveGemm`."""

    splits: int
    err_estimate: float
    calls: int = 0


class AdaptiveGemm:
    """Stateful emulated GEMM that tunes its split count per site.

    The first call for a given ``site`` measures the split count needed
    to hit ``target_rel`` on those operands and caches it; subsequent
    calls reuse the cached count.  This is the dynamic-precision
    execution mode the paper proposes for operators whose conditioning
    varies across call sites (e.g. the Green's-function poles near the
    Fermi energy in MuST).
    """

    def __init__(self, target_rel: float = 1e-9,
                 accumulator: str = "df32",
                 slice_bits: int = SLICE_BITS):
        self.target_rel = float(target_rel)
        self.accumulator = accumulator
        self.slice_bits = slice_bits
        self.sites: Dict[str, SiteState] = {}

    def __call__(self, a, b, site: str = "default", out_dtype=None):
        state = self.sites.get(site)
        if state is None:
            s, err = measure_splits(a, b, self.target_rel,
                                    accumulator=self.accumulator,
                                    slice_bits=self.slice_bits)
            state = SiteState(splits=s, err_estimate=err)
            self.sites[site] = state
        state.calls += 1
        return ozaki_matmul(a, b, num_splits=state.splits,
                            accumulator=self.accumulator,
                            out_dtype=out_dtype,
                            slice_bits=self.slice_bits)

    def report(self) -> str:
        lines = [f"AdaptiveGemm(target_rel={self.target_rel:.1e})"]
        for name, st in sorted(self.sites.items()):
            lines.append(f"  site {name!r}: s={st.splits} "
                         f"(err~{st.err_estimate:.2e}, {st.calls} calls)")
        return "\n".join(lines)
