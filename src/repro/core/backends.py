"""GEMM backend registry: one dispatch point for every execution engine.

The paper's offloading tool has a single place where an intercepted
BLAS call is redirected to an execution engine; this module is the JAX
analogue.  Every way the repo can run a matmul — native, jnp Ozaki
emulation, the Pallas fused kernel, adaptive per-site tuning — is a
:class:`GemmBackend` obtained from a *spec string*, and it is here (and
only here) that a :class:`~repro.core.precision.PrecisionPolicy` binds
to execution.  The interceptor (:mod:`repro.core.intercept`), the MuST
app, and the benchmarks all resolve their engines through
:func:`get_backend`.

Spec-string grammar
-------------------

::

    spec    := family [ "_" splits ] [ ":" arg ]
    family  := registered name ("dgemm", "fp64_int8", "pallas_int8",
               "adaptive", ...)
    splits  := integer split count, pinning the precision (e.g.
               "fp64_int8_6"); without it the policy's per-site split
               count applies
    arg     := family-specific argument (e.g. the target relative
               error of "adaptive:1e-9")

Examples: ``"dgemm"``, ``"fp64_int8_6"``, ``"fp64_int8"``,
``"pallas_int8_6"``, ``"adaptive:1e-9"``.

New engines register with :func:`register_backend`; a factory receives
the parsed spec plus the binding policy and returns the backend.
"""

from __future__ import annotations

import re
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from .ozaki import complex_matmul_via_real, ozaki_matmul
from .precision import (AdaptiveGemm, PrecisionPolicy,
                        splits_for_tolerance)

__all__ = [
    "GemmBackend",
    "register_backend",
    "get_backend",
    "registered_families",
    "example_specs",
]

_SPLITS_RE = re.compile(r"(?P<family>.+)_(?P<splits>\d+)")


class GemmBackend:
    """A 2-D matmul engine bound to a precision policy.

    Subclasses implement :meth:`matmul`; callers use the instance as a
    function.  The call contract is deliberately small so backends stay
    interchangeable inside ``vmap``/``jit`` traces:

    ``backend(a, b, out_dtype=None, num_splits=None, site="default")``

    * ``a``/``b`` — 2-D operands (real or complex floating);
    * ``out_dtype`` — result dtype (defaults to the promoted input
      dtype);
    * ``num_splits`` — call-site split request; honored unless the spec
      pinned a count (``"fp64_int8_6"`` is authoritative) and ignored
      by split-free engines (``"dgemm"``) and by ``"adaptive"``;
    * ``site`` — stable site name, used by stateful backends for
      per-site caching and by policies for per-site overrides.
    """

    #: The spec string this backend was built from (round-trips through
    #: :func:`get_backend`).
    spec: str = ""
    #: Whether the offload transform may wrap this backend's sites in
    #: the emulated-backward ``custom_vjp``.  Instrumentation backends
    #: (the tuner's calibration recorder) opt out: their side effects
    #: cannot stage through custom_vjp and their output is never
    #: differentiated.
    supports_vjp: bool = True
    #: When True, every eligible site routes through this instance,
    #: overriding per-site ``PrecisionPolicy.site_backends`` specs
    #: (again the calibration recorder: it must see the whole program).
    intercepts_all_sites: bool = False

    def __init__(self, spec: str, policy: PrecisionPolicy):
        self.spec = spec
        self.policy = policy

    def matmul(self, a, b, *, out_dtype=None, num_splits=None,
               site: str = "default"):
        raise NotImplementedError

    def __call__(self, a, b, *, out_dtype=None, num_splits=None,
                 site: str = "default"):
        return self.matmul(a, b, out_dtype=out_dtype,
                           num_splits=num_splits, site=site)

    def __repr__(self):
        return f"{type(self).__name__}({self.spec!r})"


class DgemmBackend(GemmBackend):
    """Native XLA matmul — the reference engine (and the A/B control)."""

    def matmul(self, a, b, *, out_dtype=None, num_splits=None,
               site: str = "default"):
        del num_splits, site
        c = a @ b
        return c.astype(out_dtype) if out_dtype is not None else c


class OzakiBackend(GemmBackend):
    """jnp Ozaki INT8 split-GEMM (:func:`repro.core.ozaki.ozaki_matmul`).

    A pinned spec (``"fp64_int8_6"``) is authoritative; an unpinned one
    (``"fp64_int8"``) resolves the split count per call, falling back
    to ``policy.splits_for(site)``.
    """

    def __init__(self, spec, policy, splits: Optional[int] = None):
        super().__init__(spec, policy)
        self.pinned_splits = splits

    def resolve_splits(self, num_splits, site) -> int:
        if self.pinned_splits is not None:
            return self.pinned_splits
        if num_splits is not None:
            return num_splits
        return self.policy.splits_for(site)

    def matmul(self, a, b, *, out_dtype=None, num_splits=None,
               site: str = "default"):
        return ozaki_matmul(a, b,
                            num_splits=self.resolve_splits(num_splits, site),
                            accumulator=self.policy.accumulator,
                            out_dtype=out_dtype,
                            slice_bits=self.policy.slice_bits)


class PallasBackend(OzakiBackend):
    """Fused Pallas split-GEMM kernel (:mod:`repro.kernels.ops`).

    Interpret mode is selected automatically off-TPU so the same spec
    string works everywhere.  Complex operands decompose into four real
    kernel launches (same scheme as the jnp reference path).

    Block sizes come from the analytic model in
    :mod:`repro.kernels.tile_model` — consulted per (m, k, n, s), no
    autotuning sweep.  ``"pallas_int8*:fused"`` enables in-kernel
    slicing (operands enter as f32 hi/lo pairs and are quantized
    tile-by-tile in VMEM; slices never round-trip through HBM).
    """

    def __init__(self, spec, policy, splits: Optional[int] = None,
                 fused: bool = False):
        super().__init__(spec, policy, splits)
        self.interpret = jax.default_backend() != "tpu"
        self.fused = fused

    def tile_decision(self, m, k, n, num_splits, dtype=None):
        """The model's block/schedule pick for one (m, k, n, s) site."""
        from repro.kernels import tile_model  # no Pallas dependency

        return tile_model.select_tiles(m, k, n, num_splits, dtype=dtype,
                                       fused=self.fused)

    def matmul(self, a, b, *, out_dtype=None, num_splits=None,
               site: str = "default"):
        from repro.kernels import ops  # deferred: pallas may be absent

        s = self.resolve_splits(num_splits, site)
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        if out_dtype is None:
            out_dtype = jnp.result_type(a.dtype, b.dtype)
        out_dtype = jnp.dtype(out_dtype)

        def kernel(x, y, real_out):
            tiles = self.tile_decision(x.shape[0], x.shape[1],
                                       y.shape[1], s, dtype=real_out)
            return ops.ozaki_matmul(x, y, num_splits=s,
                                    out_dtype=real_out,
                                    slice_bits=self.policy.slice_bits,
                                    interpret=self.interpret,
                                    fuse_slicing=self.fused,
                                    tiles=tiles)

        # Same complex gate as the jnp reference path (inputs OR output
        # complex), same shared four-real-GEMM decomposition.
        if jnp.issubdtype(a.dtype, jnp.complexfloating) or \
           jnp.issubdtype(b.dtype, jnp.complexfloating) or \
           jnp.issubdtype(out_dtype, jnp.complexfloating):
            return complex_matmul_via_real(kernel, a, b, out_dtype)
        return kernel(a, b, out_dtype)


class AdaptiveBackend(GemmBackend):
    """Per-site tuned emulation (:class:`repro.core.precision.AdaptiveGemm`).

    On concrete operands the first call per site probes the split count
    empirically; inside a trace (``jit``/``vmap``/the offload
    transform, where operands are abstract) it falls back to the
    a-priori model :func:`~repro.core.precision.splits_for_tolerance`,
    which only needs the static contraction extent.
    """

    def __init__(self, spec, policy, target_rel: float):
        super().__init__(spec, policy)
        self.target_rel = float(target_rel)
        self.gemm = AdaptiveGemm(target_rel=self.target_rel,
                                 accumulator=policy.accumulator,
                                 slice_bits=policy.slice_bits)

    def matmul(self, a, b, *, out_dtype=None, num_splits=None,
               site: str = "default"):
        del num_splits  # adaptivity owns the split count
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        if isinstance(a, jax.core.Tracer) or isinstance(b, jax.core.Tracer):
            s = splits_for_tolerance(self.target_rel, k=a.shape[-1],
                                     slice_bits=self.policy.slice_bits)
            return ozaki_matmul(a, b, num_splits=s,
                                accumulator=self.policy.accumulator,
                                out_dtype=out_dtype,
                                slice_bits=self.policy.slice_bits)
        return self.gemm(a, b, site=site, out_dtype=out_dtype)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

#: family -> factory(spec, policy, splits, arg) -> GemmBackend
_FACTORIES: Dict[str, Callable[..., GemmBackend]] = {}


def register_backend(family: str,
                     factory: Callable[..., GemmBackend]) -> None:
    """Register a backend family under ``family``.

    ``factory(spec, policy, splits, arg)`` receives the full spec
    string, the binding policy, the optional ``_<splits>`` suffix (as
    int) and the optional ``:<arg>`` suffix (as str), and returns the
    backend instance.
    """
    _FACTORIES[family] = factory


def registered_families() -> List[str]:
    """Sorted registered family names."""
    return sorted(_FACTORIES)


def example_specs() -> List[str]:
    """One representative, resolvable spec per registered shape.

    Used by the registry round-trip tests and the README grammar table.
    """
    return ["dgemm", "fp64_int8", "fp64_int8_6", "pallas_int8_6",
            "pallas_int8_6:fused", "adaptive:1e-9"]


def get_backend(spec: str,
                policy: PrecisionPolicy | None = None) -> GemmBackend:
    """Resolve a spec string to a :class:`GemmBackend`.

    The returned backend carries ``spec`` verbatim (round-trip:
    ``get_backend(s).spec == s``) and binds ``policy`` (accumulator,
    slice bits, per-site splits) to execution.
    """
    policy = policy or PrecisionPolicy()
    head, sep, arg = (spec or "").partition(":")
    arg = arg if sep else None
    family, splits = head, None
    if family not in _FACTORIES:
        # Longest family wins: "fp64_int8_6" is family "fp64_int8"
        # with splits 6 (the greedy match peels one digit suffix).
        m = _SPLITS_RE.fullmatch(head)
        if m and m.group("family") in _FACTORIES:
            family, splits = m.group("family"), int(m.group("splits"))
        else:
            raise ValueError(
                f"unknown backend spec {spec!r}; registered families: "
                f"{', '.join(registered_families())} "
                "(grammar: family[_<splits>][:<arg>])")
    return _FACTORIES[family](spec=spec, policy=policy, splits=splits,
                              arg=arg)


def _dgemm_factory(spec, policy, splits, arg):
    if splits is not None or arg is not None:
        raise ValueError(f"'dgemm' takes no parameters, got {spec!r}")
    return DgemmBackend(spec, policy)


def _ozaki_factory(spec, policy, splits, arg):
    if arg is not None:
        raise ValueError(f"'fp64_int8' takes no ':<arg>', got {spec!r}")
    return OzakiBackend(spec, policy, splits)


def _pallas_factory(spec, policy, splits, arg):
    if arg not in (None, "fused"):
        raise ValueError(f"'pallas_int8' accepts only ':fused' as an "
                         f"argument, got {spec!r}")
    return PallasBackend(spec, policy, splits, fused=arg == "fused")


def _adaptive_factory(spec, policy, splits, arg):
    if splits is not None:
        raise ValueError(
            f"'adaptive' tunes its own split count, got {spec!r}")
    return AdaptiveBackend(spec, policy,
                           target_rel=float(arg) if arg else 1e-9)


register_backend("dgemm", _dgemm_factory)
register_backend("fp64_int8", _ozaki_factory)
register_backend("pallas_int8", _pallas_factory)
register_backend("adaptive", _adaptive_factory)
