"""repro.configs — named, frozen run configurations for the LM stack."""

from .base import LMConfig, available_configs, get_config, register_config

__all__ = ["LMConfig", "available_configs", "get_config",
           "register_config"]
