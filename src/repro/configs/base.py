"""Model/run configuration: one frozen record, named presets, overrides.

The LM subsystem (``repro.models`` / ``repro.train`` / ``repro.serve``)
is configured by a single immutable :class:`LMConfig`.  Presets are
registered by name (``get_config("smollm_360m")``) and specialized with
``cfg.replace(num_layers=2, d_model=128)`` — the pattern the example
drivers use to scale the same architecture from CI-smoke size up to the
full model without touching model code.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

__all__ = ["LMConfig", "get_config", "register_config", "available_configs"]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    """Architecture + numerics of a llama-style decoder-only LM.

    Attributes:
      name: preset name this config was derived from.
      vocab_size: token vocabulary size.
      num_layers: number of decoder blocks (stacked, run under ``scan``).
      d_model: residual stream width.
      num_heads: query heads.
      num_kv_heads: key/value heads (GQA when ``< num_heads``).
      head_dim: per-head width (RoPE operates on this axis).
      d_ff: SwiGLU hidden width.
      max_seq_len: nominal context length (serving default; RoPE itself
        is position-parametric and does not bake this in).
      rope_theta: RoPE frequency base.
      norm_eps: RMSNorm epsilon.
      dtype: activation dtype name (``"float32"`` / ``"bfloat16"``).
      param_dtype: parameter dtype name.
      remat: rematerialize each block under ``jax.checkpoint`` (the
        offload transform inlines remat bodies, so emulated sites
        survive the recompute schedule).
      tie_embeddings: reuse the embedding matrix as the LM head.
      eos_id: end-of-sequence token id for serving, or ``None`` to
        decode until ``max_new_tokens``.
    """

    name: str = "smollm_360m"
    vocab_size: int = 49152
    num_layers: int = 32
    d_model: int = 960
    num_heads: int = 15
    num_kv_heads: int = 5
    head_dim: int = 64
    d_ff: int = 2560
    max_seq_len: int = 2048
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    dtype: str = "float32"
    param_dtype: str = "float32"
    remat: bool = False
    tie_embeddings: bool = False
    eos_id: Optional[int] = None

    def __post_init__(self):
        if self.num_heads % self.num_kv_heads:
            raise ValueError(
                f"num_heads={self.num_heads} must be a multiple of "
                f"num_kv_heads={self.num_kv_heads}")
        if self.head_dim % 2:
            raise ValueError(f"head_dim={self.head_dim} must be even "
                             "(RoPE rotates half-dim pairs)")

    def replace(self, **overrides) -> "LMConfig":
        """A copy with ``overrides`` applied (validation re-runs)."""
        return dataclasses.replace(self, **overrides)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def num_params(self) -> int:
        """Exact parameter count of :meth:`repro.models.lm.Model.init_params`."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        per_block = (2 * d                       # the two norms
                     + d * self.q_dim + 2 * d * self.kv_dim
                     + self.q_dim * d            # attention
                     + 2 * d * f + f * d)        # SwiGLU
        head = 0 if self.tie_embeddings else d * v
        return v * d + self.num_layers * per_block + d + head


_CONFIGS: Dict[str, LMConfig] = {}


def register_config(cfg: LMConfig) -> LMConfig:
    """Register ``cfg`` under ``cfg.name``; returns it for chaining."""
    _CONFIGS[cfg.name] = cfg
    return cfg


def available_configs():
    """Sorted registered preset names."""
    return sorted(_CONFIGS)


def get_config(name: str) -> LMConfig:
    """Look up a preset by name.

    The returned config is frozen; specialize with ``.replace(...)``.
    """
    try:
        return _CONFIGS[name]
    except KeyError:
        raise ValueError(f"unknown config {name!r}; available: "
                         f"{', '.join(available_configs())}") from None


# SmolLM-360M geometry (the paper-scale serving target of the ROADMAP
# dry runs); the examples shrink it with .replace for CPU runs.
register_config(LMConfig(name="smollm_360m"))

# A CI/test-scale preset: two blocks at d128 — large enough that the
# projection GEMMs clear the default offload size gate (m=k=n >= 128
# once batch*seq >= 128) while a full train step stays sub-second on
# CPU, small enough that attention (k = head_dim = 32) stays native.
register_config(LMConfig(
    name="tiny", vocab_size=512, num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
    max_seq_len=256))

# CPU-sized reductions of the same architecture, used by the example
# drivers (examples/train_lm.py presets "reduced" and "100m").
register_config(LMConfig(
    name="reduced", vocab_size=4096, num_layers=6, d_model=256,
    num_heads=8, num_kv_heads=4, head_dim=32, d_ff=1024,
    max_seq_len=1024))
register_config(LMConfig(
    name="reduced_100m", vocab_size=16384, num_layers=12, d_model=1024,
    num_heads=16, num_kv_heads=8, head_dim=64, d_ff=2816,
    max_seq_len=2048))
