"""Roofline analysis of dry-run artifacts (paper §4 performance model).

The container has no accelerator, so TPU-side performance claims are
made through a roofline model evaluated over *dry-run artifacts*: JSON
files describing the per-cell work of a lowered program (flops, HBM
bytes, collective bytes, device count).  :func:`analyze_cell` converts
one artifact into the three roofline times and names the binding
resource — the same decomposition the paper uses to argue when INT8
emulation pays off (compute-bound GEMM cells gain the full
int8/fp64-unit ratio; memory- or collective-bound cells do not).

Artifact schema (all numeric fields optional, default 0)::

    {
      "cell": "must_n4096_pod16x16",   # any label
      "num_devices": 256,
      "flops": 1.2e15,                  # total programme flops
      "int8_flops": 9.6e14,             # flops issued as INT8 MACs
      "hbm_bytes": 3.1e12,
      "collective_bytes": 4.0e10,
      "peaks": {                        # optional hardware override
        "flops": 1.97e14, "int8_flops": 3.94e14,
        "hbm_gbps": 8.19e11, "ici_gbps": 4.5e10
      }
    }

Per-device peaks default to TPU v5e: 197 TFLOPS bf16 / 394 TOPS int8,
819 GB/s HBM, 45 GB/s ICI per link.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Union

__all__ = ["V5E_PEAKS", "CellAnalysis", "analyze_cell"]

#: Per-device peak rates (TPU v5e).
V5E_PEAKS: Dict[str, float] = {
    "flops": 1.97e14,        # bf16/f32-accumulate MXU FLOP/s
    "int8_flops": 3.94e14,   # INT8 MAC/s — the emulation substrate
    "hbm_gbps": 8.19e11,     # HBM bytes/s
    "ici_gbps": 4.5e10,      # ICI bytes/s per link
}


@dataclasses.dataclass
class CellAnalysis:
    """Roofline times (seconds) for one dry-run cell."""

    cell: str
    num_devices: int
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        times = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(times, key=times.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def analyze_cell(artifact: Union[str, Path, Dict]) -> CellAnalysis:
    """Evaluate the roofline model for one dry-run JSON artifact.

    ``artifact`` may be a path to a JSON file or an already-parsed
    dict.  Raises ``ValueError`` on artifacts missing a usable label
    or carrying non-numeric work counts.
    """
    if isinstance(artifact, (str, Path)):
        path = Path(artifact)
        data = json.loads(path.read_text())
        default_cell = path.stem
    else:
        data = dict(artifact)
        default_cell = "cell"
    if not isinstance(data, dict):
        raise ValueError(f"artifact must be a JSON object, got "
                         f"{type(data).__name__}")

    cell = str(data.get("cell", default_cell))
    ndev = int(data.get("num_devices", 1) or 1)
    peaks = dict(V5E_PEAKS)
    peaks.update(data.get("peaks", {}))

    def work(key):
        v = data.get(key, 0.0)
        if not isinstance(v, (int, float)):
            raise ValueError(f"field {key!r} must be numeric, got {v!r}")
        return float(v)

    # Mixed-precision compute: f32/bf16 flops ride the MXU peak, the
    # INT8-emulated portion rides the (2x faster) int8 peak.
    f_total = work("flops")
    f_int8 = min(work("int8_flops"), f_total)
    compute_s = ((f_total - f_int8) / peaks["flops"]
                 + f_int8 / peaks["int8_flops"]) / ndev
    memory_s = work("hbm_bytes") / peaks["hbm_gbps"] / ndev
    collective_s = work("collective_bytes") / peaks["ici_gbps"] / ndev
    return CellAnalysis(cell=cell, num_devices=ndev,
                        compute_s=compute_s, memory_s=memory_s,
                        collective_s=collective_s)
