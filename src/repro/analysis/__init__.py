"""repro.analysis — performance models over dry-run artifacts."""

from . import roofline

__all__ = ["roofline"]
