"""MuST-style Green's-function contour workload (paper §3.2 / §4).

MuST (LSMS family) spends its time inverting the KKR multiple-
scattering matrix at every energy point of a contour around the Fermi
energy; the inversion is a *blocked* LU driver (``zblock_lu``) whose
flops are almost entirely ZGEMM — exactly the calls the paper's
offloading tool redirects to INT8 emulation.

This module reproduces that structure on a synthetic-but-physical
stand-in: a dense Hermitian "Hamiltonian" with an eigenvalue cluster
near the Fermi energy.  For each energy ``z`` on a contour just above
the real axis we form ``M = z I - H`` and compute the resolvent
``G(z) = M^{-1}`` by blocked LU factorization plus blocked triangular
solves, where **every block GEMM goes through a registry backend**
(:mod:`repro.core.backends` — any spec string works as a mode):

* ``"dgemm"``          — native float64 complex matmul (reference);
* ``"fp64_int8_{s}"``  — Ozaki INT8 emulation with ``s`` splits;
* ``"pallas_int8_{s}"``— the fused Pallas kernel (interpret on CPU);
* ``"adaptive:{tol}"`` — per-site split tuning to a target error.

Small per-block factorizations (the LAPACK part MuST keeps on the
host) remain native float64 in all modes, so the accuracy difference
between modes isolates the GEMM emulation — the quantity the paper's
Table 1 reports.  The poles of ``G`` near the Fermi energy amplify the
emulation error locally, reproducing the isolated error peak of the
paper's Figure 1, and contour-integrated observables (electron-count
and band-energy analogues) converge to the FP64 values as the split
count grows.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax.numpy as jnp
import numpy as np

from repro.core.backends import get_backend
from repro.core.precision import PrecisionPolicy

__all__ = ["MustConfig", "build_system", "run_contour",
           "relative_errors"]


@dataclasses.dataclass
class MustConfig:
    """Synthetic LSMS system + contour discretization."""

    n: int = 384            # scattering-matrix dimension
    block: int = 96         # zblock_lu block size
    n_energies: int = 16    # contour points
    fermi: float = 0.72     # Fermi energy (Ryd), where G has poles
    eta: float = 0.03       # contour height above the real axis
    e_min: float = 0.12     # contour start (Ryd)
    e_max: float = 1.32     # contour end (Ryd)
    cluster_frac: float = 0.25  # fraction of states near the Fermi energy
    cluster_width: float = 0.04
    seed: int = 0

    def __post_init__(self):
        if self.n % self.block != 0:
            raise ValueError(
                f"block {self.block} must divide n {self.n}")


def build_system(cfg: MustConfig) -> Dict[str, np.ndarray]:
    """Random Hermitian Hamiltonian with a state cluster at E_f.

    Eigenvalues are drawn uniformly over the contour window except for
    a ``cluster_frac`` share packed within ``cluster_width`` of the
    Fermi energy — those poles sit right under the contour and make
    ``G(z)`` locally ill-conditioned, which is what gives the paper's
    Figure 1 its isolated error peak.
    """
    rng = np.random.default_rng(cfg.seed)
    n = cfg.n
    n_cluster = int(round(cfg.cluster_frac * n))
    evals = np.concatenate([
        rng.uniform(cfg.e_min - 0.1, cfg.e_max + 0.1, n - n_cluster),
        cfg.fermi + cfg.cluster_width * rng.standard_normal(n_cluster),
    ])
    # Random unitary eigenbasis via QR of a complex Ginibre matrix.
    z = rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))
    q, r = np.linalg.qr(z)
    q = q * (np.diagonal(r) / np.abs(np.diagonal(r)))
    h = (q * evals) @ q.conj().T
    h = 0.5 * (h + h.conj().T)  # exact Hermitian symmetrization
    return {"H": h, "evals": np.sort(evals)}


def _make_gemm(mode: str) -> Callable[[np.ndarray, np.ndarray],
                                      np.ndarray]:
    """Resolve a mode string to a numpy-in/numpy-out block GEMM.

    The mode string is a backend spec (see
    :func:`repro.core.backends.get_backend` for the grammar); the bound
    policy selects the ``"f64"`` accumulator, the historical choice of
    this workload (it mirrors ozIMMU on FP64-capable hardware).
    """
    backend = get_backend(mode, policy=PrecisionPolicy(accumulator="f64"))

    def gemm(a: np.ndarray, b: np.ndarray) -> np.ndarray:
        c = backend(jnp.asarray(a), jnp.asarray(b),
                    out_dtype=jnp.complex128, site="zblock_lu")
        return np.asarray(c)

    return gemm


def _blocked_inverse(m_mat: np.ndarray, block: int, gemm) -> np.ndarray:
    """``m_mat^{-1}`` via blocked LU + blocked triangular solves.

    Mirrors MuST's zblock_lu: the O(n^3) work — Schur updates and the
    substitution products — is all block GEMMs through ``gemm``; only
    the per-diagonal-block inversions are native LAPACK.
    """
    n = m_mat.shape[0]
    nb = n // block
    sl = [slice(i * block, (i + 1) * block) for i in range(nb)]

    # Block Doolittle LU (no pivoting: z I - H with Im z > 0 keeps the
    # diagonal blocks well away from singular).  L has identity
    # diagonal blocks; U is the remaining upper factor.
    a = m_mat.copy()
    lower = np.zeros_like(a)
    for k in range(nb):
        inv_kk = np.linalg.inv(a[sl[k], sl[k]])
        lower[sl[k], sl[k]] = np.eye(block)
        for i in range(k + 1, nb):
            lower[sl[i], sl[k]] = gemm(a[sl[i], sl[k]], inv_kk)
        for i in range(k + 1, nb):
            upd = gemm(lower[sl[i], sl[k]], a[sl[k], k * block:])
            a[sl[i], k * block:] -= upd
    upper = a
    for i in range(1, nb):
        for j in range(i):
            upper[sl[i], sl[j]] = 0.0

    # Forward substitution  L Y = I   (unit block diagonal).
    y = np.zeros_like(a)
    ident = np.eye(n, dtype=a.dtype)
    for i in range(nb):
        acc = ident[sl[i], :].copy()
        for j in range(i):
            acc -= gemm(lower[sl[i], sl[j]], y[sl[j], :])
        y[sl[i], :] = acc

    # Backward substitution  U G = Y.  Applying the diagonal-block
    # inverse is itself a block GEMM — route it through the backend
    # too, so *all* O(n^3) work is emulated (only the O(block^3)
    # LAPACK inversions stay native, as in MuST).
    g = np.zeros_like(a)
    for i in range(nb - 1, -1, -1):
        acc = y[sl[i], :].copy()
        for j in range(i + 1, nb):
            acc -= gemm(upper[sl[i], sl[j]], g[sl[j], :])
        g[sl[i], :] = gemm(np.linalg.inv(upper[sl[i], sl[i]]), acc)
    return g


def contour_points(cfg: MustConfig):
    """Energy contour and trapezoid weights just above the real axis."""
    e = np.linspace(cfg.e_min, cfg.e_max, cfg.n_energies)
    z = e + 1j * cfg.eta
    w = np.gradient(e)
    return z, w


def run_contour(cfg: MustConfig, mode: str,
                system: Dict[str, np.ndarray]) -> Dict:
    """Sweep ``G(z) = (z I - H)^{-1}`` over the contour in one mode.

    Returns per-energy diagonals of G (the site-resolved Green's
    function MuST feeds to its density integrator), the trace, and
    the contour-integrated observables:

    * ``ne``   — electron-count analogue: -1/pi Im sum_k w_k Tr G(z_k);
    * ``etot`` — band-energy analogue:    -1/pi Im sum_k w_k z_k Tr G.
    """
    gemm = _make_gemm(mode)
    h = system["H"]
    z, w = contour_points(cfg)
    n = cfg.n
    g_diag = np.zeros((cfg.n_energies, n), dtype=np.complex128)
    tr_g = np.zeros(cfg.n_energies, dtype=np.complex128)
    for idx, zk in enumerate(z):
        m_mat = zk * np.eye(n, dtype=np.complex128) - h
        g = _blocked_inverse(m_mat, cfg.block, gemm)
        g_diag[idx] = np.diagonal(g)
        tr_g[idx] = np.trace(g)
    ne = float(-np.imag(np.sum(w * tr_g)) / np.pi)
    etot = float(-np.imag(np.sum(w * z * tr_g)) / np.pi)
    return {"mode": mode, "z": z, "weights": w, "g_diag": g_diag,
            "tr_g": tr_g, "ne": ne, "etot": etot}


def relative_errors(ref: Dict, test: Dict) -> Dict:
    """Paper Table-1 metrics: Re/Im errors of G plus observable drifts.

    Per-energy errors are normalized by the largest |component| of the
    reference at that energy (so the Figure-1 profile shows where the
    *relative* accuracy degrades, i.e. near the poles at E_f).
    """
    dre = np.abs(np.real(test["g_diag"]) - np.real(ref["g_diag"]))
    dim = np.abs(np.imag(test["g_diag"]) - np.imag(ref["g_diag"]))
    norm_re = np.max(np.abs(np.real(ref["g_diag"])), axis=1)
    norm_im = np.max(np.abs(np.imag(ref["g_diag"])), axis=1)
    per_z_real = np.max(dre, axis=1) / np.where(norm_re == 0, 1, norm_re)
    per_z_imag = np.max(dim, axis=1) / np.where(norm_im == 0, 1, norm_im)
    return {
        "per_z_real": per_z_real,
        "per_z_imag": per_z_imag,
        "max_real": float(np.max(per_z_real)),
        "max_imag": float(np.max(per_z_imag)),
        "d_etot": abs(test["etot"] - ref["etot"]) / max(
            1e-30, abs(ref["etot"])),
        "d_ne": abs(test["ne"] - ref["ne"]) / max(
            1e-30, abs(ref["ne"])),
    }
