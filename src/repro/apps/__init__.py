"""repro.apps — paper workloads driven through the emulation engine."""

from . import must

__all__ = ["must"]
