"""Llama-style decoder-only LM as pure-JAX programs.

Three programs over one parameter pytree:

* :meth:`Model.apply` / :meth:`Model.loss` — full-context causal
  forward (training).  Blocks are stacked along a leading layer axis
  and run under ``jax.lax.scan`` (optionally rematerialized), so the
  traced program is one block body — exactly the shape the automatic
  offload transform (:mod:`repro.core.intercept`) descends into: the
  projection/MLP/head matmuls appear as ``scan{i}/dot{j}`` sites and
  get routed through the GEMM backend registry, while the attention
  ``QK^T``/``AV`` contractions (``k = head_dim``) stay under the size
  gate and run native.
* :meth:`Model.prefill` — batched prompt ingestion into a fresh KV
  cache (right-padded prompts, per-slot true lengths), returning the
  last-real-token logits.
* :meth:`Model.decode_step` — one greedy-decoding step against the
  cache (one token per slot, per-slot positions).

The *dense* cache layout is ``(num_layers, batch, kv_heads, max_len,
head_dim)`` so the layer axis lines up with the stacked block
parameters and both cache-touching programs are the same ``scan``.

The *paged* cache layout (:meth:`Model.init_paged_cache` plus the
``*_paged`` / ``prefill_chunk*`` programs) replaces the per-slot
``max_len`` rectangle with a shared pool of fixed-size blocks
``(num_layers, num_blocks, kv_heads, block_size, head_dim)`` addressed
through a per-slot block table — slots only consume blocks they have
actually written (see :mod:`repro.serve.kvcache` for the allocator).
The gathered attention view is bit-identical to the dense buffer, so
paged == dense is an exact equivalence, not an approximate one.

No framework dependency (flax/optax are not in the container): params
are plain dicts, initialization is explicit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import LMConfig

__all__ = ["Model"]

# Finite mask value: -inf breaks softmax rows that are fully masked
# (inactive serve slots attend to nothing real); a large negative
# float32 yields harmless uniform attention there instead of NaNs.
_MASK_VALUE = -1e30


def _rms_norm(x, weight, eps):
    # At-least-f32: f32 for f32/bf16 activations (unchanged), f64 for
    # an f64 model — a hardcoded f32 here would push the *weight
    # gradient* (a cross-batch reduction) down to f32, capping the
    # data-parallel == single-device training equivalence at f32 ulps.
    dt = jnp.promote_types(x.dtype, jnp.float32)
    h = x.astype(dt)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * weight.astype(dt)).astype(x.dtype)


def _rope(x, positions, theta):
    """Rotate half-dim pairs of ``x`` (..., T, H, head_dim).

    ``positions`` is (..., T) — absolute positions, so cached keys and
    fresh queries agree on the rotation regardless of where in the
    sequence this call starts.
    """
    half = x.shape[-1] // 2
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over the head axis
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def _sdpa(q, k, v, mask):
    """Softmax(QK^T / sqrt(d)) V with a boolean keep-mask.

    q: (B, T, H, d); k, v: (B, S, H, d); mask: (B, T, S) True = attend.
    Scores are computed and normalized in float32.
    """
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bthd,bshd->bhts", q, k).astype(jnp.float32)
    scores = scores * scale
    scores = jnp.where(mask[:, None, :, :], scores, _MASK_VALUE)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", attn.astype(v.dtype), v)
    return out


def _tp_enter(axis):
    """Identity forward, ``psum`` over ``axis`` backward.

    Megatron's ``f``: wraps the (replicated) input of a tensor-parallel
    block.  Each tp shard's backward produces only its partial
    contribution to the cotangent; the psum completes it, so the
    residual stream and every replicated parameter upstream see the
    full gradient.
    """
    @jax.custom_vjp
    def f(x):
        return x

    f.defvjp(lambda x: (x, None),
             lambda _, g: (jax.lax.psum(g, axis),))
    return f


def _tp_exit(axis):
    """``psum`` over ``axis`` forward, identity backward.

    Megatron's ``g``: closes a tensor-parallel block after the
    row-parallel matmul (``wo`` / ``w_down``), summing the per-shard
    partial products into the replicated output.  The backward is the
    identity because the incoming cotangent is already replicated.
    """
    @jax.custom_vjp
    def g(x):
        return jax.lax.psum(x, axis)

    g.defvjp(lambda x: (jax.lax.psum(x, axis), None),
             lambda _, ct: (ct,))
    return g


class Model:
    """A decoder-only LM bound to an :class:`~repro.configs.LMConfig`.

    All methods are pure functions of ``(params, ...)`` and safe to
    ``jit`` / ``grad`` / wrap in :func:`repro.core.intercept.offload`.

    ``tp_axis`` (a mesh axis name) switches the block math to
    Megatron-style tensor parallelism for use *inside* a ``shard_map``
    body: the attention projections and the SwiGLU hidden dim are
    column-parallel (each shard holds ``num_heads/tp`` heads and
    ``d_ff/tp`` hidden columns), ``wo``/``w_down`` are row-parallel,
    and each sublayer output is completed with one ``lax.psum`` over
    ``tp_axis``.  The head counts are derived from the *local*
    parameter shapes, so the same code runs the full model
    (``tp_axis=None``) and any shard width.  Gradients of replicated
    parameters (norms, embeddings, head) are completed by the
    identity-forward/psum-backward wrapper around each block input, so
    ``value_and_grad`` of :meth:`loss` is exact per shard.
    """

    def __init__(self, cfg: LMConfig, tp_axis: str | None = None):
        self.cfg = cfg
        self.tp_axis = tp_axis
        self.dtype = jnp.dtype(cfg.dtype)
        self.param_dtype = jnp.dtype(cfg.param_dtype)

    def _tp_in(self, x):
        return _tp_enter(self.tp_axis)(x) if self.tp_axis else x

    def _tp_out(self, x):
        return _tp_exit(self.tp_axis)(x) if self.tp_axis else x

    # -- parameters --------------------------------------------------

    def init_params(self, rng) -> dict:
        """Initialize the parameter pytree.

        Projections get scaled-normal init; the LM head starts at zero
        (untied), so the initial loss is exactly ``log(vocab)`` and the
        first optimizer steps descend monotonically — which is what the
        smoke examples assert.
        """
        cfg = self.cfg
        keys = jax.random.split(rng, 8)
        L, d, f = cfg.num_layers, cfg.d_model, cfg.d_ff

        def init(key, shape, scale):
            w = scale * jax.random.normal(key, shape, dtype=jnp.float32)
            return w.astype(self.param_dtype)

        s_in = d ** -0.5
        s_out = s_in / (2 * L) ** 0.5  # residual-branch damping
        params = {
            "embed": init(keys[0], (cfg.vocab_size, d), 0.02),
            "blocks": {
                "attn_norm": jnp.ones((L, d), self.param_dtype),
                "wq": init(keys[1], (L, d, cfg.q_dim), s_in),
                "wk": init(keys[2], (L, d, cfg.kv_dim), s_in),
                "wv": init(keys[3], (L, d, cfg.kv_dim), s_in),
                "wo": init(keys[4], (L, cfg.q_dim, d), s_out),
                "mlp_norm": jnp.ones((L, d), self.param_dtype),
                "w_gate": init(keys[5], (L, d, f), s_in),
                "w_up": init(keys[6], (L, d, f), s_in),
                "w_down": init(keys[7], (L, f, d), s_out),
            },
            "final_norm": jnp.ones((d,), self.param_dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = jnp.zeros((d, cfg.vocab_size),
                                          self.param_dtype)
        return params

    # -- shared block pieces -----------------------------------------

    def _qkv(self, lp, x, positions):
        """Project + reshape + rope.  x: (B, T, d) -> q/k/v heads.

        Head counts come from the projection shapes, not the config,
        so under tensor parallelism (column-sharded ``wq``/``wk``/
        ``wv``) each shard produces its ``num_heads / tp`` local heads
        from the same code.
        """
        cfg = self.cfg
        B, T = x.shape[:2]
        h = self._tp_in(_rms_norm(x, lp["attn_norm"], cfg.norm_eps))
        q = (h @ lp["wq"]).reshape(B, T, -1, cfg.head_dim)
        k = (h @ lp["wk"]).reshape(B, T, -1, cfg.head_dim)
        v = (h @ lp["wv"]).reshape(B, T, -1, cfg.head_dim)
        q = _rope(q, positions, cfg.rope_theta)
        k = _rope(k, positions, cfg.rope_theta)
        return q, k, v

    def _attn_out(self, lp, x, o):
        B, T = x.shape[:2]
        o = o.reshape(B, T, -1)
        return x + self._tp_out(o @ lp["wo"])

    def _mlp(self, lp, x):
        h = self._tp_in(_rms_norm(x, lp["mlp_norm"],
                                  self.cfg.norm_eps))
        gate = jax.nn.silu((h @ lp["w_gate"]).astype(jnp.float32))
        up = (h @ lp["w_up"]).astype(jnp.float32)
        return x + self._tp_out((gate * up).astype(x.dtype)
                                @ lp["w_down"])

    def _repeat_kv(self, kv, num_heads):
        """(B, S, KV, d) -> (B, S, H, d) for grouped-query attention.

        ``num_heads`` is the query head count *of this shard* (under
        tp, ``cfg.num_heads / tp``), so the group size is preserved.
        """
        rep = num_heads // kv.shape[2]
        return jnp.repeat(kv, rep, axis=2) if rep > 1 else kv

    def _head(self, params, x):
        """Final norm + LM head on (..., d) activations."""
        x = _rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        head = (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])
        return x @ head

    # -- full-context forward (training) -----------------------------

    def apply(self, params, tokens) -> jax.Array:
        """Causal logits for ``tokens`` (B, T) -> (B, T, vocab)."""
        cfg = self.cfg
        B, T = tokens.shape
        x = params["embed"][tokens].astype(self.dtype)
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))
        causal = jnp.tril(jnp.ones((T, T), bool))
        mask = jnp.broadcast_to(causal, (B, T, T))

        def block(x, lp):
            q, k, v = self._qkv(lp, x, positions)
            H = q.shape[2]
            o = _sdpa(q, self._repeat_kv(k, H), self._repeat_kv(v, H),
                      mask)
            x = self._attn_out(lp, x, o)
            x = self._mlp(lp, x)
            return x, None

        if cfg.remat:
            block = jax.checkpoint(block)
        x, _ = jax.lax.scan(block, x, params["blocks"])
        return self._head(params, x)

    def loss(self, params, tokens) -> jax.Array:
        """Mean causal cross-entropy over ``tokens`` (B, T+1).

        Computed in at-least-f32: f32 for f32/bf16 activations
        (unchanged), f64 for an f64 model — downcasting would cap
        data-parallel == single-device loss agreement at f32 ulps.
        """
        inputs, targets = tokens[:, :-1], tokens[:, 1:]
        logits = self.apply(params, inputs)
        logits = logits.astype(jnp.promote_types(logits.dtype,
                                                 jnp.float32))
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
        return jnp.mean(nll)

    # -- KV-cache programs (serving) ---------------------------------

    def init_cache(self, batch: int, max_len: int) -> dict:
        """Empty cache: stacked K/V buffers + per-slot lengths."""
        cfg = self.cfg
        shape = (cfg.num_layers, batch, cfg.num_kv_heads, max_len,
                 cfg.head_dim)
        return {"k": jnp.zeros(shape, self.dtype),
                "v": jnp.zeros(shape, self.dtype),
                "length": jnp.zeros((batch,), jnp.int32)}

    def _cached_forward(self, params, cache, tokens, start):
        """Shared prefill/decode body.

        tokens: (B, T) new tokens; start: (B,) their first absolute
        position (0 for prefill, current length for decode).  Writes
        the new K/V at ``start..start+T-1`` per slot, attends over the
        whole buffer under a key_pos <= query_pos mask, and returns
        ``(new_cache_kv, hidden (B, T, d))``.
        """
        cfg = self.cfg
        B, T = tokens.shape
        S = cache["k"].shape[3]
        x = params["embed"][tokens].astype(self.dtype)
        positions = start[:, None] + jnp.arange(T)          # (B, T)
        key_pos = jnp.arange(S)                             # (S,)
        # Causal over absolute positions; anything above the query's
        # position is either future or stale buffer garbage — masked.
        mask = key_pos[None, None, :] <= positions[:, :, None]

        def write(buf, new, p):
            # buf: (KV, S, d); new: (T, KV, d); p: scalar start.  All
            # three start indices must share p's dtype (int32) or x64
            # mode promotes the literal zeros to int64.
            zero = jnp.zeros((), p.dtype)
            return jax.lax.dynamic_update_slice(
                buf, jnp.moveaxis(new, 0, 1), (zero, p, zero))

        def block(x, layer):
            lp, k_buf, v_buf = layer
            q, k, v = self._qkv(lp, x, positions)
            k_buf = jax.vmap(write)(k_buf, k, start)
            v_buf = jax.vmap(write)(v_buf, v, start)
            k_all = jnp.moveaxis(k_buf, 1, 2)  # (B, S, KV, d)
            v_all = jnp.moveaxis(v_buf, 1, 2)
            H = q.shape[2]
            o = _sdpa(q, self._repeat_kv(k_all, H),
                      self._repeat_kv(v_all, H), mask)
            x = self._attn_out(lp, x, o)
            x = self._mlp(lp, x)
            return x, (k_buf, v_buf)

        x, (k_new, v_new) = jax.lax.scan(
            block, x, (params["blocks"], cache["k"], cache["v"]))
        return k_new, v_new, x

    def prefill(self, params, tokens, lengths, max_len: int):
        """Ingest right-padded prompts into a fresh cache.

        tokens: (b, P) prompts padded to a common length P; lengths:
        (b,) true prompt lengths.  Returns ``(cache, last_logits)``
        where ``last_logits`` (b, vocab) are taken at each prompt's
        final real token.  Padding positions do get written to the
        buffer, but decode queries never attend past ``length`` and the
        next decode write overwrites position ``length`` first.
        """
        b = tokens.shape[0]
        cache = self.init_cache(b, max_len)
        start = jnp.zeros((b,), jnp.int32)
        k_new, v_new, x = self._cached_forward(params, cache, tokens,
                                               start)
        last = jnp.take_along_axis(
            x, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)
        logits = self._head(params, last[:, 0, :])
        return ({"k": k_new, "v": v_new,
                 "length": lengths.astype(jnp.int32)}, logits)

    def decode_step(self, params, cache, tokens, active):
        """One decoding step: consume ``tokens`` (B,), emit next logits.

        ``active`` (B, bool) gates the length bump so idle slots don't
        creep toward the buffer end; their K/V writes land at their
        stale ``length`` and are overwritten on the next admission.
        """
        start = cache["length"]
        k_new, v_new, x = self._cached_forward(params, cache,
                                               tokens[:, None], start)
        logits = self._head(params, x[:, 0, :])
        new_len = jnp.where(active, start + 1, start)
        return ({"k": k_new, "v": v_new, "length": new_len}, logits)

    def prefill_chunk(self, params, k, v, tokens, start, piece_len):
        """One chunk of a (possibly multi-wave) dense prefill.

        k/v: gathered cache rows (L, rows, KV, max_len, d) for the
        slots in this wave; tokens: (rows, W) chunk tokens right-padded
        to the wave width; start: (rows,) absolute position of each
        chunk's first token; piece_len: (rows,) true chunk lengths.
        Returns the updated rows plus logits at each chunk's last real
        token (only meaningful for chunks that complete their prompt).

        Chunk padding is written at ``start + piece_len ..`` within the
        slot's own rectangle and overwritten by the next chunk/decode
        write before anything attends to it, exactly like the padded
        tail of an unchunked prefill wave.
        """
        k_new, v_new, x = self._cached_forward(
            params, {"k": k, "v": v}, tokens, start)
        last = jnp.take_along_axis(
            x, (piece_len - 1)[:, None, None].astype(jnp.int32), axis=1)
        logits = self._head(params, last[:, 0, :])
        return k_new, v_new, logits

    # -- paged KV-cache programs (serving) ---------------------------

    def init_paged_cache(self, num_blocks: int, block_size: int) -> dict:
        """Empty K/V block pools for the paged cache layout.

        The block table and per-slot lengths are owned by the allocator
        (:class:`repro.serve.kvcache.PagedKVCache`), which assembles
        the full cache dict around these pools.
        """
        cfg = self.cfg
        shape = (cfg.num_layers, num_blocks, cfg.num_kv_heads,
                 block_size, cfg.head_dim)
        return {"k": jnp.zeros(shape, self.dtype),
                "v": jnp.zeros(shape, self.dtype)}

    def _paged_forward(self, params, k_pool, v_pool, table, tokens,
                       start, write_mask):
        """Shared paged prefill/decode body (block-table indirection).

        table: (B, nb + 1) int32 physical block ids; entry ``j`` maps
        the slot's logical block ``j`` (positions ``j*bs .. j*bs+bs-1``)
        into the pool, and the *trailing column* is the slot's trash
        block — writes of padded / inactive positions are routed there
        instead of at a real block, so chunk padding and masked decode
        writes can never corrupt another slot's cache.  write_mask:
        (B, T) bool, True where the token is real.

        The per-slot view gathered for attention is laid out exactly
        like the dense buffer's ``(B, S, KV, d)`` with
        ``S = nb * block_size``: every unmasked position holds the same
        written value, every masked position is squashed to the same
        ``_MASK_VALUE`` score and an exactly-zero attention weight —
        which is what makes paged == dense *bitwise*, not just close.
        """
        cfg = self.cfg
        B, T = tokens.shape
        nb = table.shape[1] - 1
        bs = k_pool.shape[3]
        S = nb * bs
        x = params["embed"][tokens].astype(self.dtype)
        positions = start[:, None] + jnp.arange(T)          # (B, T)
        key_pos = jnp.arange(S)
        mask = key_pos[None, None, :] <= positions[:, :, None]

        # Destination of each new token: logical block + offset, mapped
        # through the table; padded tokens index the trash column.
        col = jnp.where(write_mask, positions // bs, nb)
        phys = jnp.take_along_axis(table, col, axis=1)      # (B, T)
        flat_phys = phys.reshape(-1)
        flat_off = (positions % bs).reshape(-1)
        attend = table[:, :nb]                              # (B, nb)

        def write(pool, new):
            # pool: (NB, KV, bs, d); new: (B, T, KV, d).  The advanced
            # indices at dims 0/2 broadcast to the front, so updates
            # are (B*T, KV, d).  Trash-block collisions are fine: that
            # block is only ever read under the mask.
            return pool.at[flat_phys, :, flat_off, :].set(
                new.reshape(B * T, new.shape[2], new.shape[3]))

        def gather(pool):
            # (B, nb, KV, bs, d) -> the dense buffer's (B, KV, S, d),
            # then the dense path's own moveaxis.  Going through the
            # buffer layout is load-bearing for bitwise paged == dense:
            # feeding the attention einsum a differently-laid-out (but
            # value-identical) operand changes the GEMM's accumulation
            # order on CPU by ~1 ulp.
            buf = pool[attend].transpose(0, 2, 1, 3, 4).reshape(
                B, -1, S, cfg.head_dim)
            return jnp.moveaxis(buf, 1, 2)                  # (B, S, KV, d)

        def block(x, layer):
            lp, kp, vp = layer
            q, k, v = self._qkv(lp, x, positions)
            kp = write(kp, k)
            vp = write(vp, v)
            k_all = gather(kp)
            v_all = gather(vp)
            H = q.shape[2]
            o = _sdpa(q, self._repeat_kv(k_all, H),
                      self._repeat_kv(v_all, H), mask)
            x = self._attn_out(lp, x, o)
            x = self._mlp(lp, x)
            return x, (kp, vp)

        x, (k_new, v_new) = jax.lax.scan(
            block, x, (params["blocks"], k_pool, v_pool))
        return k_new, v_new, x

    def prefill_chunk_paged(self, params, k, v, table, tokens, start,
                            piece_len):
        """Paged analogue of :meth:`prefill_chunk` over the block pools.

        k/v are the *whole* pools (every wave writes through the block
        table, no gather/scatter of rows); table holds the wave rows'
        block-table entries (incl. the trash column).
        """
        T = tokens.shape[1]
        write_mask = jnp.arange(T)[None, :] < piece_len[:, None]
        k_new, v_new, x = self._paged_forward(
            params, k, v, table, tokens, start, write_mask)
        last = jnp.take_along_axis(
            x, (piece_len - 1)[:, None, None].astype(jnp.int32), axis=1)
        logits = self._head(params, last[:, 0, :])
        return k_new, v_new, logits

    def decode_step_paged(self, params, cache, tokens, active):
        """One decoding step against the paged cache.

        Same contract as :meth:`decode_step`; inactive slots' writes
        are routed to their trash block (the dense path writes them at
        the stale length instead), and the length bump is gated the
        same way.
        """
        start = cache["length"]
        k_new, v_new, x = self._paged_forward(
            params, cache["k"], cache["v"], cache["block_table"],
            tokens[:, None], start, active[:, None])
        logits = self._head(params, x[:, 0, :])
        new_len = jnp.where(active, start + 1, start)
        return ({"k": k_new, "v": v_new,
                 "block_table": cache["block_table"],
                 "length": new_len}, logits)

    def greedy(self, logits) -> jax.Array:
        """Greedy token choice (B, vocab) -> (B,) int32."""
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
