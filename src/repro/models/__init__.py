"""repro.models — neural workloads built on the GEMM registry."""

from .lm import Model

__all__ = ["Model"]
