"""Analytic tile-cost model for the v2 fused split-GEMM kernel.

Closed-form selection of ``block_m/n/k`` and the slice-pair schedule
per ``(m, k, n, s, dtype)`` — no autotuning sweep.  Three quantities
are modeled, all hand-computable from the constants below:

* **VMEM footprint** of one grid step: double-buffered input blocks,
  in-kernel slicing scratch (fused mode), and the resident hi/lo f32
  output accumulator tiles.  A candidate block shape is admissible only
  if the footprint fits :attr:`TPUParams.vmem_budget`.
* **MXU issue cycles** per int8 tile product: the 128x128 systolic
  array retires one 128x128x128 MAC block per 128 cycles, so a
  ``(bm, bk) @ (bk, bn)`` tile costs ``ceil(bm/128) * ceil(bn/128) *
  ceil(bk/128) * 128`` cycles.
* **HBM bytes per grid step**: the kernel streams one A block and one
  B block per step (1 byte/elem int8 pre-sliced, 8 bytes/elem for the
  two f32 halves in fused mode); hi/lo output tiles are written once
  per (m, n) tile because the reduction dims (pair, k-tile) iterate
  innermost.

Candidates are scored by the per-flop bottleneck time
``max(mxu_cycles, hbm_cycles) / (bm*bn*bk)`` with deterministic tie
breaks, so the same inputs always select the same tiles — plans stay
byte-identical across meshes and machines.

The model is also the accounting authority for the v1 -> v2 traffic
claim: v1 materialized every slice *pair* in HBM (``s*(s+1)/2`` gathered
copies of the slice arrays — O(s²·m·k) bytes staged and read), while v2
keeps the ``(s, m, k)``/``(s, k, n)`` slice arrays intact and picks the
pair from the grid via BlockSpec index maps, so the slice data read from
HBM drops to O(s·m·k) — a ``(s+1)/2``x read reduction (3.5x at s=6).
:func:`traffic` reports both so benchmarks can gate on the ratio.

Nothing in this module imports Pallas: the tuner and the offload
interceptor consult it on hosts where ``jax.experimental.pallas`` may
be unavailable.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.ozaki import num_pair_gemms, pair_indices

__all__ = [
    "TPUParams",
    "TileDecision",
    "Traffic",
    "align_up",
    "pair_schedule",
    "vmem_bytes",
    "mxu_tile_cycles",
    "hbm_bytes_per_step",
    "traffic",
    "select_tiles",
    "split_cost",
]

# Minimum int8 tile on the TPU MXU: 32 sublanes x 128 lanes.  Every
# block dimension the kernel uses must be a multiple of these.
SUBLANE_INT8 = 32
LANE = 128


@dataclasses.dataclass(frozen=True)
class TPUParams:
    """Hardware constants the model prices against (TPU v5e defaults).

    ``bytes_per_cycle`` (HBM bandwidth per core clock) and
    ``macs_per_cycle`` (one 128x128 systolic column step) are the only
    two rates the score uses, so the model stays a two-resource
    roofline: a block shape is memory-bound when streaming its inputs
    takes longer than issuing its MACs.
    """

    vmem_budget: int = 16 * 1024 * 1024   # bytes of VMEM per core
    mxu_dim: int = 128                    # systolic array edge
    clock_hz: float = 940e6               # core clock
    hbm_bw: float = 819e9                 # bytes/s of HBM bandwidth

    @property
    def bytes_per_cycle(self) -> float:
        return self.hbm_bw / self.clock_hz

    @property
    def macs_per_cycle(self) -> int:
        return self.mxu_dim * self.mxu_dim


DEFAULT_PARAMS = TPUParams()

# Candidate block sizes enumerated by select_tiles.  Small by design:
# the score below is exact arithmetic, so enumerating ~3x3x3 shapes is
# a closed-form pick, not an autotuning sweep.
_BM_CANDIDATES = (32, 64, 128, 256)
_BN_CANDIDATES = (128, 256, 512)
_BK_CANDIDATES = (128, 256, 512)


def align_up(x: int, multiple: int) -> int:
    """Round ``x`` up to a multiple of ``multiple`` (min one multiple)."""
    return max(multiple, ((x + multiple - 1) // multiple) * multiple)


def pair_schedule(num_splits: int, mode: str = "ordered"):
    """Slice-pair visit order (ii, jj) for the kernel's pair grid dim.

    ``"ordered"`` — by ascending total shift ``i + j`` (largest weight
    first), identical to :func:`repro.core.ozaki.pair_indices`.  This is
    the only schedule the kernel runs: compensated accumulation order is
    part of the bit-identity contract with the jnp df32 reference.

    ``"grouped"`` — by A-slice index ``i`` so consecutive grid steps
    reuse the resident A block.  Evaluated for traffic accounting only;
    running it would reorder the TwoSum stream and break bit-identity.
    """
    ii, jj = pair_indices(num_splits)
    if mode == "ordered":
        return ii, jj
    if mode == "grouped":
        order = sorted(range(len(ii)), key=lambda p: (ii[p], jj[p]))
        return ii[order], jj[order]
    raise ValueError(f"unknown pair schedule {mode!r};"
                     " expected 'ordered' or 'grouped'")


def vmem_bytes(bm: int, bn: int, bk: int, *, fused: bool = False) -> int:
    """VMEM footprint of one grid step, in bytes.

    Input blocks are double-buffered (x2, the Pallas pipeline overlaps
    the next DMA with the current product).  Fused mode streams two f32
    halves per operand (8 bytes/elem) and needs int8 slice scratch for
    the quantized tiles; pre-sliced mode streams int8 (1 byte/elem).
    The hi/lo f32 output accumulator tiles stay resident.
    """
    in_elems = bm * bk + bk * bn
    if fused:
        in_bytes = 2 * 4 * in_elems   # hi + lo f32 halves
        scratch = in_elems            # int8 quantized tiles
    else:
        in_bytes = in_elems           # int8 slices
        scratch = 0
    out_bytes = 2 * 4 * bm * bn       # hi + lo f32 accumulators
    return 2 * in_bytes + scratch + out_bytes


def mxu_tile_cycles(bm: int, bn: int, bk: int,
                    params: TPUParams = DEFAULT_PARAMS) -> int:
    """MXU issue cycles for one (bm, bk) @ (bk, bn) int8 tile product."""
    d = params.mxu_dim
    return (math.ceil(bm / d) * math.ceil(bn / d) * math.ceil(bk / d)
            * params.mxu_dim)


def hbm_bytes_per_step(bm: int, bn: int, bk: int, *,
                       fused: bool = False) -> int:
    """Bytes streamed from HBM by one grid step (one A + one B block)."""
    elem_bytes = 8 if fused else 1  # f32 hi+lo halves vs int8 slices
    return elem_bytes * (bm * bk + bk * bn)


@dataclasses.dataclass(frozen=True)
class Traffic:
    """Modeled HBM bytes for one emulated GEMM, v1 vs v2.

    ``slice_read_bytes_*`` count the slice data the kernel path must
    read: v1 reads ``s*(s+1)/2`` gathered pair copies, v2 reads the
    ``s`` slice arrays — the O(s²) -> O(s) reduction.  ``stage`` adds
    the staging writes (and the gather's reads) that produce what the
    kernel consumes; ``stream`` is the per-grid-step block traffic
    (identical shape v1/v2 — the win is staging, which is why
    ``read_reduction`` is defined on the slice reads); ``out`` is the
    hi/lo f32 result write.
    """

    slice_read_bytes_v1: int
    slice_read_bytes_v2: int
    stage_bytes_v1: int
    stage_bytes_v2: int
    stream_bytes: int
    out_bytes: int

    @property
    def total_v1(self) -> int:
        return self.stage_bytes_v1 + self.stream_bytes + self.out_bytes

    @property
    def total_v2(self) -> int:
        return self.stage_bytes_v2 + self.stream_bytes + self.out_bytes

    @property
    def read_reduction(self) -> float:
        """Slice bytes read, v1 / v2 == (s + 1) / 2."""
        return self.slice_read_bytes_v1 / self.slice_read_bytes_v2


def traffic(m: int, k: int, n: int, num_splits: int,
            bm: int, bn: int, bk: int, *, fused: bool = False) -> Traffic:
    """Model the HBM bytes one emulated (m, k) @ (k, n) GEMM moves.

    All counts use the padded dims the kernel actually runs on.  In
    fused mode the slices never exist in HBM: staging is the f32 hi/lo
    halves (8 bytes/elem) and the "slice read" is the halves stream.
    """
    mp, kp, np_ = align_up(m, bm), align_up(k, bk), align_up(n, bn)
    elems = mp * kp + kp * np_            # one slice layer, A + B
    pairs = num_pair_gemms(num_splits)
    grid = (mp // bm) * (np_ // bn) * pairs * (kp // bk)
    stream = grid * hbm_bytes_per_step(bm, bn, bk, fused=fused)
    out = 2 * 4 * mp * np_                # hi + lo f32
    # v1: build s slice layers (write), gather s(s+1)/2 pair copies
    # (read the source layers + write the copies).
    v1_read = pairs * elems
    v1_stage = num_splits * elems + 2 * pairs * elems
    if fused:
        v2_read = num_splits * elems      # each layer decoded s times in VMEM
        v2_stage = 2 * 4 * elems          # write the f32 hi/lo halves once
    else:
        v2_read = num_splits * elems      # the (s, ., .) arrays, once each
        v2_stage = num_splits * elems     # slice build writes
    return Traffic(slice_read_bytes_v1=v1_read,
                   slice_read_bytes_v2=v2_read,
                   stage_bytes_v1=v1_stage,
                   stage_bytes_v2=v2_stage,
                   stream_bytes=stream,
                   out_bytes=out)


@dataclasses.dataclass(frozen=True)
class TileDecision:
    """The model's pick for one GEMM site (everything derived, no sweep)."""

    block_m: int
    block_n: int
    block_k: int
    num_splits: int
    pairs: int                    # pair-schedule length s*(s+1)/2
    schedule: str                 # always "ordered" (bit-identity)
    fused: bool
    vmem_bytes: int               # footprint of one grid step
    mxu_cycles_step: int          # issue cycles per tile product
    hbm_bytes_step: int           # streamed bytes per grid step
    # Shape-dependent totals; None when selected canonically (m/n
    # unknown, e.g. for plan recording where tiles must not depend on
    # per-shard geometry).
    kernel_invocations: int | None = None
    traffic_model: Traffic | None = None

    def summary(self) -> dict:
        """Compact dict for Site records / plan JSON / obs events."""
        return {"block_m": self.block_m, "block_n": self.block_n,
                "block_k": self.block_k, "pairs": self.pairs,
                "schedule": self.schedule}


def _candidates(dim: int | None, options, multiple: int):
    """Admissible block sizes for one dim: aligned, not past the padded
    extent (picking a block larger than align_up(dim) only adds pad)."""
    if dim is None:
        return list(options)
    cap = align_up(dim, multiple)
    cands = [c for c in options if c <= cap]
    return cands or [options[0]]


def select_tiles(m: int | None, k: int | None, n: int | None,
                 num_splits: int, dtype=None, *, fused: bool = False,
                 params: TPUParams = DEFAULT_PARAMS) -> TileDecision:
    """Pick ``block_m/n/k`` for an emulated GEMM — closed form, no sweep.

    Pass ``m``/``n`` (and ``k``) as ``None`` for the *canonical*
    decision that depends only on split count and mode — what tuned
    plans record, so a plan solved on a dp=8 mesh is byte-identical to
    one solved on a single device regardless of per-shard geometry.

    ``dtype`` is accepted for the (m, k, n, s, dtype) contract; the
    kernel streams int8 slices (or f32 halves when fused) whatever the
    source dtype, so it does not change the pick today.
    """
    del dtype
    best = None
    best_key = None
    for bm in _candidates(m, _BM_CANDIDATES, SUBLANE_INT8):
        for bn in _candidates(n, _BN_CANDIDATES, LANE):
            for bk in _candidates(k, _BK_CANDIDATES, LANE):
                vb = vmem_bytes(bm, bn, bk, fused=fused)
                if vb > params.vmem_budget:
                    continue
                mxu = mxu_tile_cycles(bm, bn, bk, params)
                hbm = hbm_bytes_per_step(bm, bn, bk, fused=fused)
                hbm_cycles = hbm / params.bytes_per_cycle
                flops = bm * bn * bk
                score = max(mxu, hbm_cycles) / flops
                # Deterministic tie-breaks: per-flop bottleneck time,
                # then per-flop HBM traffic (favor reuse), then the
                # largest block (fewest invocations), then lexicographic.
                key = (score, hbm / flops, -flops, bm, bn, bk)
                if best_key is None or key < best_key:
                    best_key = key
                    best = (bm, bn, bk, vb, mxu, hbm)
    if best is None:  # pragma: no cover - smallest candidate always fits
        raise ValueError("no block shape fits the VMEM budget")
    bm, bn, bk, vb, mxu, hbm = best
    pairs = num_pair_gemms(num_splits)
    invocations = None
    tm = None
    if m is not None and k is not None and n is not None:
        mp, kp, np_ = align_up(m, bm), align_up(k, bk), align_up(n, bn)
        invocations = (mp // bm) * (np_ // bn) * pairs * (kp // bk)
        tm = traffic(m, k, n, num_splits, bm, bn, bk, fused=fused)
    return TileDecision(block_m=bm, block_n=bn, block_k=bk,
                        num_splits=num_splits, pairs=pairs,
                        schedule="ordered", fused=fused, vmem_bytes=vb,
                        mxu_cycles_step=mxu, hbm_bytes_step=hbm,
                        kernel_invocations=invocations, traffic_model=tm)


# Nominal output extent used to convert the slice-stream bytes of
# split_cost into MXU-cycle units without knowing m/n (the tuner prices
# sites by k and flops only; 1024 matches the LM examples' hidden dims).
_NOMINAL_EXTENT = 1024


def split_cost(num_splits: int,
               params: TPUParams = DEFAULT_PARAMS) -> float:
    """Modeled cost of one emulated GEMM at split ``s``, in units of
    one pair-GEMM's MXU time — the tuner's replacement for the bare
    ``n_pairs(s)`` proxy.

    cost(s) = pairs(s) + s * slice_tax, where the tax converts the O(s)
    slice-array read (v2 traffic model) into pair-GEMM units::

        slice_tax = macs_per_cycle * (1/m + 1/n) / bytes_per_cycle

    at the nominal extent above.  The tax is small (~0.04 pair-GEMMs
    per slice on v5e numbers): v2 is compute-bound, which is exactly
    the paper's roofline argument — but the term keeps the solver's
    marginal costs honest about the traffic each extra split adds.
    """
    tax = (params.macs_per_cycle * (2.0 / _NOMINAL_EXTENT)
           / params.bytes_per_cycle)
    return num_pair_gemms(num_splits) + num_splits * tax
