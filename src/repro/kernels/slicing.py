"""In-kernel slicing primitives shared by the fused kernel and jnp.

The v2 fused path quantizes operands to int8 slices *inside* the
Pallas kernel, tile by tile in VMEM, so slices never round-trip
through HBM.  TPU HBM carries no f64, so a high-precision operand
enters the kernel as an exact pair of f32 halves ``(hi, lo)`` with
``hi + lo == r`` (for f32 inputs ``lo == 0`` and every step below
reproduces :func:`repro.core.ozaki.slice_matrix` bit-for-bit; for f64
inputs the pair carries ~48 mantissa bits — the same budget the df32
accumulator keeps).

Everything here is plain jnp: the Pallas kernel body calls these
helpers on VMEM tiles, and :func:`slice_matrix_fused` runs the exact
same arithmetic as a whole-matrix jnp program so interpret-mode tests
can pin the kernel bit-for-bit against a reference that never touches
Pallas.  Only :mod:`repro.kernels.ops` imports Pallas.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ozaki import SLICE_BITS, _pow2_scale, _two_sum

__all__ = [
    "to_f32_pair",
    "to_operand_pair",
    "slice_step",
    "quantize_tile",
    "slice_matrix_fused",
]


def to_f32_pair(r):
    """Exact f32 decomposition ``r == hi + lo`` (lo == 0 for f32 ``r``).

    ``hi`` is ``r`` rounded to f32; ``lo`` is the remainder, itself
    representable in f32 because the cancellation in ``r - hi`` leaves
    at most a mantissa's worth of trailing bits.
    """
    hi = r.astype(jnp.float32)
    lo = (r - hi.astype(r.dtype)).astype(jnp.float32)
    return hi, lo


def to_operand_pair(x, axis: int):
    """Scale ``x`` by its power-of-two sigma and decompose to f32 halves.

    The shared preamble of the fused kernel wrapper and of
    :func:`slice_matrix_fused` — one definition so the kernel and its
    jnp reference cannot drift.  Returns ``(hi, lo, sigma)`` with
    ``sigma`` squeezed like :func:`repro.core.ozaki.slice_matrix`'s.
    """
    compute_dtype = (jnp.float64 if jax.config.jax_enable_x64
                     else jnp.float32)
    x = x.astype(compute_dtype)
    sigma = _pow2_scale(x, axis=axis)
    hi, lo = to_f32_pair(x / sigma)
    return hi, lo, jnp.squeeze(sigma, axis=axis)


def slice_step(hi, lo, radix: float):
    """One slicing step on an f32 pair: extract q, return the residue.

    Mirrors the reference recurrence ``q = round(r*radix); r = r*radix
    - q`` in pair arithmetic.  Every operation is exact: ``radix`` is a
    power of two, ``yh - q`` cancels only leading bits (|yh + yl| <=
    radix/2 + 1 so q is a small integer), and TwoSum re-normalizes the
    residue pair.  The invariant ``hi + lo == r_exact`` therefore holds
    through every step, which is what makes the fused kernel's slices
    equal to :func:`slice_matrix_fused`'s bit-for-bit.
    """
    yh = hi * radix
    yl = lo * radix
    q = jnp.round(yh + yl)
    r = yh - q
    hi2, lo2 = _two_sum(r, yl)
    return q, hi2, lo2


def quantize_tile(hi, lo, index, num_splits: int,
                  slice_bits: int = SLICE_BITS):
    """Quantize an f32-pair tile and return slice ``index`` as int8.

    ``index`` may be a traced scalar (the kernel reads it from the
    scalar-prefetch pair schedule).  The loop length is static
    (``num_splits``), so this lowers to a fixed chain of exact ops plus
    ``num_splits`` selects — no gather, no HBM.
    """
    radix = float(2 ** slice_bits)
    sel = jnp.zeros(hi.shape, jnp.int8)
    for t in range(num_splits):
        q, hi, lo = slice_step(hi, lo, radix)
        sel = jnp.where(t == index, q.astype(jnp.int8), sel)
    return sel


def slice_matrix_fused(x, num_splits: int, axis: int,
                       slice_bits: int = SLICE_BITS):
    """Whole-matrix jnp reference for the fused kernel's slicing.

    Same contract as :func:`repro.core.ozaki.slice_matrix` — returns
    ``(slices, sigma)`` — but computed through the f32-pair recurrence
    the kernel runs in VMEM.  For f32 inputs the two agree bit-for-bit
    (``lo == 0`` makes every pair step collapse to the reference
    recurrence); for f64 inputs this *is* the spec the kernel is tested
    against, truncated to the pair's ~48 mantissa bits.
    """
    hi, lo, sigma = to_operand_pair(x, axis)
    radix = float(2 ** slice_bits)
    out = []
    for _ in range(num_splits):
        q, hi, lo = slice_step(hi, lo, radix)
        out.append(q.astype(jnp.int8))
    return jnp.stack(out), sigma
