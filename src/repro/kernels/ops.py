"""Pallas tiled Ozaki split-GEMM kernel.

One fused kernel computes the whole emulated GEMM: the grid walks
``(m-tiles, n-tiles, slice-pairs, k-tiles)`` and every step issues one
INT8xINT8->INT32 tile product on the MXU, weights it by the pair's
power-of-two shift, and folds it into a compensated float32 accumulator
held in VMEM scratch (TwoSum, so the ~48-bit "df32" accuracy of the
reference path survives the single-f32 output constraint of FP64-free
hardware).  The kernel emits separate hi/lo f32 outputs; the wrapper
combines them in the requested output dtype.

Slicing (mantissa decomposition) happens outside the kernel with the
same helpers as :mod:`repro.core.ozaki`, so both paths are bit-for-bit
comparable in tests.

On CPU there is no Mosaic backend: pass ``interpret=True`` (the
benchmarks do) to run the kernel through the Pallas interpreter —
correctness-only, but it exercises the exact same kernel body that
compiles for TPU.

TPU notes: int8 operands want (32, 128) min tiles; the default 128
tile sizes below satisfy MXU alignment for all dtypes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.ozaki import (SLICE_BITS, _two_sum, pair_indices,
                              slice_matrix)

__all__ = ["ozaki_matmul", "split_gemm_pallas"]


def _split_gemm_kernel(a_ref, b_ref, w_ref, hi_ref, lo_ref):
    """Grid: (m/bm, n/bn, num_pairs, k/bk). One INT8 tile product.

    The output tiles are revisited across the two reduction grid dims
    (pair index, k-tile) and double as the compensated accumulator:
    ``hi`` carries the running TwoSum, ``lo`` the accumulated error.
    """
    p = pl.program_id(2)
    kt = pl.program_id(3)
    first = jnp.logical_and(p == 0, kt == 0)

    @pl.when(first)
    def _():
        hi_ref[...] = jnp.zeros_like(hi_ref)
        lo_ref[...] = jnp.zeros_like(lo_ref)

    part = jax.lax.dot_general(
        a_ref[0], b_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    # Power-of-two pair weight: the product is exact in f32 because the
    # int32 partial fits f32's mantissa for k-tiles <= 2**(24-2w+2).
    term = part.astype(jnp.float32) * w_ref[0]

    # Same compensated accumulation as the jnp df32 reference path —
    # shared TwoSum keeps the two paths bit-identical by construction.
    s, err = _two_sum(hi_ref[...], term)
    hi_ref[...] = s
    lo_ref[...] = lo_ref[...] + err


def _pad_to(x, multiple, axis):
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=(
    "num_splits", "slice_bits", "block_m", "block_n", "block_k",
    "interpret"))
def split_gemm_pallas(a_sl, b_sl, num_splits: int,
                      slice_bits: int = SLICE_BITS,
                      block_m: int = 128, block_n: int = 128,
                      block_k: int = 128, interpret: bool = False):
    """Run the fused pair-product kernel over pre-sliced operands.

    Args:
      a_sl: (s, m, k) int8 slices of A.
      b_sl: (s, k, n) int8 slices of B.

    Returns:
      (hi, lo) float32 arrays of shape (m, n); the emulated scaled
      product is ``(hi + lo) * 2**(-slice_bits*(num_splits+1))`` (the
      deferred shift keeps all in-kernel weights >= 1 so they stay
      exact in f32).
    """
    _, m, k = a_sl.shape
    _, _, n = b_sl.shape
    ii, jj = pair_indices(num_splits)
    smax = num_splits - 1
    a_pairs = jnp.take(a_sl, jnp.asarray(ii), axis=0)
    b_pairs = jnp.take(b_sl, jnp.asarray(jj), axis=0)
    weights = jnp.asarray(
        np.ldexp(np.float32(1.0), (smax - (ii + jj)) * slice_bits))

    bm, bn, bk = (min(block_m, m), min(block_n, n), min(block_k, k))
    a_pairs = _pad_to(_pad_to(a_pairs, bm, 1), bk, 2)
    b_pairs = _pad_to(_pad_to(b_pairs, bk, 1), bn, 2)
    mp, kp = a_pairs.shape[1:]
    np_ = b_pairs.shape[2]
    num_pairs = len(ii)
    grid = (mp // bm, np_ // bn, num_pairs, kp // bk)

    hi, lo = pl.pallas_call(
        _split_gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda i, j, p, kt: (p, i, kt)),
            pl.BlockSpec((1, bk, bn), lambda i, j, p, kt: (p, kt, j)),
            pl.BlockSpec((1,), lambda i, j, p, kt: (p,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, p, kt: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, p, kt: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        interpret=interpret,
    )(a_pairs, b_pairs, weights)
    return hi[:m, :n], lo[:m, :n]


def ozaki_matmul(a, b, num_splits: int = 6, accumulator: str = "df32",
                 out_dtype=None, slice_bits: int = SLICE_BITS,
                 interpret: bool = False, block_m: int = 128,
                 block_n: int = 128, block_k: int = 128):
    """Pallas-backed drop-in for :func:`repro.core.ozaki.ozaki_matmul`.

    Same signature and semantics as the jnp reference path, plus
    ``interpret`` (run through the Pallas interpreter — required on
    CPU) and tile-size overrides.  The kernel's compensated-f32
    accumulation corresponds to the reference ``"df32"`` accumulator;
    ``accumulator`` is accepted for signature parity.
    """
    del accumulator  # kernel always accumulates compensated-f32
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError("ozaki_matmul expects 2-D operands, got "
                         f"{a.shape} @ {b.shape}")
    if out_dtype is None:
        out_dtype = jnp.result_type(a.dtype, b.dtype)
    out_dtype = jnp.dtype(out_dtype)
    if jnp.issubdtype(out_dtype, jnp.complexfloating):
        raise NotImplementedError(
            "complex operands: route through repro.core.ozaki_matmul")

    a_sl, sigma_a = slice_matrix(a, num_splits, axis=1,
                                 slice_bits=slice_bits)
    b_sl, sigma_b = slice_matrix(b, num_splits, axis=0,
                                 slice_bits=slice_bits)
    hi, lo = split_gemm_pallas(a_sl, b_sl, num_splits,
                               slice_bits=slice_bits, block_m=block_m,
                               block_n=block_n, block_k=block_k,
                               interpret=interpret)
    deferred = 2.0 ** (-slice_bits * (num_splits + 1))
    c = (hi.astype(out_dtype) + lo.astype(out_dtype)) * deferred
    scale = (sigma_a[:, None] * sigma_b[None, :]).astype(out_dtype)
    return c * scale
