"""Pallas tiled Ozaki split-GEMM kernels (v2 fused pair-indexing).

One kernel computes the whole emulated GEMM: the grid walks
``(m-tiles, n-tiles, slice-pairs, k-tiles)`` and every step issues one
INT8xINT8->INT32 tile product on the MXU, weights it by the pair's
power-of-two shift, and folds it into a compensated float32 accumulator
held in the revisited output tiles (TwoSum, so the ~48-bit "df32"
accuracy of the reference path survives the single-f32 output
constraint of FP64-free hardware).  The kernel emits separate hi/lo
f32 outputs; the wrapper combines them in the requested output dtype.

**v2 (default,** :func:`split_gemm_pallas` **)** never materializes
slice pairs: the slices stay as one ``(s, m, k)`` / ``(s, k, n)``
array and the pair ``(i, j)`` for each grid step is looked up from a
scalar-prefetch pair schedule (``pltpu.PrefetchScalarGridSpec``) inside
the BlockSpec index maps; the pair weight is reconstructed in-kernel
from its integer exponent by exact bit manipulation.  HBM slice reads
drop from the O(s²·m·k) gathered pair copies of v1 to the O(s·m·k)
slice arrays themselves (see :mod:`repro.kernels.tile_model`, the
accounting authority).  The legacy pair-materializing kernel survives
as :func:`split_gemm_pallas_v1` for A/B equivalence tests and the
traffic benchmarks.

**Fused slicing** (:func:`split_gemm_pallas_fused`, opt-in via
``ozaki_matmul(..., fuse_slicing=True)`` or the ``pallas_int8*:fused``
backend spec) goes further: operands enter as exact f32 hi/lo halves
and are quantized to int8 tile-by-tile in VMEM with
:mod:`repro.kernels.slicing`, so slices never exist in HBM at all.

Slicing arithmetic is shared with :mod:`repro.core.ozaki` /
:mod:`repro.kernels.slicing`, so all paths are bit-for-bit comparable
in tests.

On CPU there is no Mosaic backend: pass ``interpret=True`` (the
benchmarks do) to run the kernel through the Pallas interpreter —
correctness-only, but it exercises the exact same kernel body that
compiles for TPU.

**Tile alignment rule**: int8 operands on the TPU MXU require (32,
128) minimum tiles, so every block dimension is rounded *up* to a
valid multiple — ``block_m`` to 32, ``block_n``/``block_k`` to 128 —
after clamping to the operand's own padded extent (a block larger than
``align_up(dim)`` only adds dead padding).  Small or ragged shapes are
therefore zero-padded up to one aligned tile rather than shrinking the
block below MXU alignment (the old ``min(block_m, m)`` clamp emitted
unlowerable sub-(32, 128) tiles for small sites).  Zero padding is
exact: padded rows/columns contribute nothing to any slice product.
Block sizes default to the analytic model in
:mod:`repro.kernels.tile_model` — no autotuning sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.ozaki import (SLICE_BITS, _two_sum, pair_indices,
                              slice_matrix)
from repro.kernels import slicing, tile_model
from repro.kernels.tile_model import LANE, SUBLANE_INT8, align_up

__all__ = [
    "ozaki_matmul",
    "split_gemm_pallas",
    "split_gemm_pallas_fused",
    "split_gemm_pallas_v1",
]


def _pow2_f32(e):
    """Exact f32 ``2.0**e`` from an int32 exponent via bit assembly.

    Valid for e in [-126, 127]; the kernels only need non-negative
    shifts <= (s-1)*slice_bits.  Avoids ``exp2`` (inexact on some
    backends) and table lookups inside the kernel.
    """
    return jax.lax.bitcast_convert_type(
        ((e + 127) << 23).astype(jnp.int32), jnp.float32)


def _accumulate(hi_ref, lo_ref, part, w, first):
    """Weight one INT32 tile product and fold it into the hi/lo refs.

    The shared tail of every kernel body: the power-of-two weight keeps
    the term exact in f32 (the int32 partial fits f32's mantissa for
    k-tiles <= 2**(24-2*slice_bits+2)), and the TwoSum is the same
    compensated step as the jnp df32 reference path — shared arithmetic
    keeps the paths bit-identical by construction.
    """
    @pl.when(first)
    def _():
        hi_ref[...] = jnp.zeros_like(hi_ref)
        lo_ref[...] = jnp.zeros_like(lo_ref)

    term = part.astype(jnp.float32) * w
    s, err = _two_sum(hi_ref[...], term)
    hi_ref[...] = s
    lo_ref[...] = lo_ref[...] + err


def _split_gemm_kernel_v2(ii_ref, jj_ref, wexp_ref, a_ref, b_ref,
                          hi_ref, lo_ref):
    """Grid: (m/bm, n/bn, num_pairs, k/bk). One INT8 tile product.

    The slice pair for step ``p`` was already selected by the BlockSpec
    index maps (scalar-prefetch ``ii``/``jj``); the kernel only has to
    reconstruct the pair weight from its prefetched integer exponent.
    Output tiles are revisited across the two reduction grid dims
    (pair index, k-tile) and double as the compensated accumulator.
    """
    p = pl.program_id(2)
    kt = pl.program_id(3)
    del ii_ref, jj_ref  # consumed by the index maps
    part = jax.lax.dot_general(
        a_ref[0], b_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    w = _pow2_f32(wexp_ref[p])
    _accumulate(hi_ref, lo_ref, part, w,
                jnp.logical_and(p == 0, kt == 0))


def _split_gemm_kernel_fused(ii_ref, jj_ref, wexp_ref, ah_ref, al_ref,
                             bh_ref, bl_ref, hi_ref, lo_ref, *,
                             num_splits, slice_bits):
    """Fused variant: quantize f32-pair tiles to int8 in VMEM first."""
    p = pl.program_id(2)
    kt = pl.program_id(3)
    a_q = slicing.quantize_tile(ah_ref[...], al_ref[...], ii_ref[p],
                                num_splits, slice_bits)
    b_q = slicing.quantize_tile(bh_ref[...], bl_ref[...], jj_ref[p],
                                num_splits, slice_bits)
    part = jax.lax.dot_general(
        a_q, b_q,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    w = _pow2_f32(wexp_ref[p])
    _accumulate(hi_ref, lo_ref, part, w,
                jnp.logical_and(p == 0, kt == 0))


def _split_gemm_kernel_v1(a_ref, b_ref, w_ref, hi_ref, lo_ref):
    """Legacy v1 body: operands are pre-gathered (pairs, ., .) arrays."""
    p = pl.program_id(2)
    kt = pl.program_id(3)
    part = jax.lax.dot_general(
        a_ref[0], b_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    _accumulate(hi_ref, lo_ref, part, w_ref[0],
                jnp.logical_and(p == 0, kt == 0))


def _pad_to(x, multiple, axis):
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _block(dim: int, requested: int, multiple: int) -> int:
    """Aligned block size: clamp to the padded extent, round up to the
    MXU multiple (the module-docstring alignment rule)."""
    return align_up(min(requested, align_up(dim, multiple)), multiple)


def _pair_schedule_arrays(num_splits: int, slice_bits: int):
    """(ii, jj, wexp) int32 device arrays for the scalar-prefetch grid."""
    ii, jj = pair_indices(num_splits)
    smax = num_splits - 1
    wexp = (smax - (ii + jj)) * slice_bits
    return (jnp.asarray(ii, jnp.int32), jnp.asarray(jj, jnp.int32),
            jnp.asarray(wexp, jnp.int32))


@functools.partial(jax.jit, static_argnames=(
    "num_splits", "slice_bits", "block_m", "block_n", "block_k",
    "interpret"))
def split_gemm_pallas(a_sl, b_sl, num_splits: int,
                      slice_bits: int = SLICE_BITS,
                      block_m: int = 128, block_n: int = 128,
                      block_k: int = 128, interpret: bool = False):
    """Run the v2 pair-indexing kernel over pre-sliced operands.

    Args:
      a_sl: (s, m, k) int8 slices of A.
      b_sl: (s, k, n) int8 slices of B.

    Returns:
      (hi, lo) float32 arrays of shape (m, n); the emulated scaled
      product is ``(hi + lo) * 2**(-slice_bits*(num_splits+1))`` (the
      deferred shift keeps all in-kernel weights >= 1 so they stay
      exact in f32).

    Unlike v1 this never gathers slice pairs: the scalar-prefetch
    schedule drives the BlockSpec index maps straight into the
    ``(s, ., .)`` slice arrays, so HBM holds (and the grid reads) s
    slice layers instead of s*(s+1)/2 pair copies.
    """
    _, m, k = a_sl.shape
    _, _, n = b_sl.shape
    ii, jj, wexp = _pair_schedule_arrays(num_splits, slice_bits)
    num_pairs = ii.shape[0]

    bm = _block(m, block_m, SUBLANE_INT8)
    bn = _block(n, block_n, LANE)
    bk = _block(k, block_k, LANE)
    a_sl = _pad_to(_pad_to(a_sl, bm, 1), bk, 2)
    b_sl = _pad_to(_pad_to(b_sl, bk, 1), bn, 2)
    mp, kp = a_sl.shape[1:]
    np_ = b_sl.shape[2]
    grid = (mp // bm, np_ // bn, num_pairs, kp // bk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk),
                         lambda i, j, p, kt, ii, jj, we: (ii[p], i, kt)),
            pl.BlockSpec((1, bk, bn),
                         lambda i, j, p, kt, ii, jj, we: (jj[p], kt, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn),
                         lambda i, j, p, kt, ii, jj, we: (i, j)),
            pl.BlockSpec((bm, bn),
                         lambda i, j, p, kt, ii, jj, we: (i, j)),
        ],
    )
    hi, lo = pl.pallas_call(
        _split_gemm_kernel_v2,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        interpret=interpret,
    )(ii, jj, wexp, a_sl, b_sl)
    return hi[:m, :n], lo[:m, :n]


@functools.partial(jax.jit, static_argnames=(
    "num_splits", "slice_bits", "block_m", "block_n", "block_k",
    "interpret"))
def split_gemm_pallas_fused(a_hi, a_lo, b_hi, b_lo, num_splits: int,
                            slice_bits: int = SLICE_BITS,
                            block_m: int = 128, block_n: int = 128,
                            block_k: int = 128,
                            interpret: bool = False):
    """v2 kernel with in-VMEM slicing: operands as exact f32 pairs.

    Args:
      a_hi, a_lo: (m, k) f32 halves of the sigma-scaled A
        (``repro.kernels.slicing.to_operand_pair``).
      b_hi, b_lo: (k, n) f32 halves of the sigma-scaled B.

    Same (hi, lo) contract as :func:`split_gemm_pallas`.  Slices never
    exist in HBM: each grid step re-derives its int8 tile from the f32
    pair in VMEM (schedule/weights identical, so results match the
    pre-sliced path bit-for-bit when the slices agree — exactly, for
    f32 sources).
    """
    m, k = a_hi.shape
    _, n = b_hi.shape
    ii, jj, wexp = _pair_schedule_arrays(num_splits, slice_bits)
    num_pairs = ii.shape[0]

    bm = _block(m, block_m, SUBLANE_INT8)
    bn = _block(n, block_n, LANE)
    bk = _block(k, block_k, LANE)
    a_hi, a_lo = (_pad_to(_pad_to(x, bm, 0), bk, 1) for x in (a_hi, a_lo))
    b_hi, b_lo = (_pad_to(_pad_to(x, bk, 0), bn, 1) for x in (b_hi, b_lo))
    mp, kp = a_hi.shape
    np_ = b_hi.shape[1]
    grid = (mp // bm, np_ // bn, num_pairs, kp // bk)

    a_spec = pl.BlockSpec((bm, bk),
                          lambda i, j, p, kt, ii, jj, we: (i, kt))
    b_spec = pl.BlockSpec((bk, bn),
                          lambda i, j, p, kt, ii, jj, we: (kt, j))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[a_spec, a_spec, b_spec, b_spec],
        out_specs=[
            pl.BlockSpec((bm, bn),
                         lambda i, j, p, kt, ii, jj, we: (i, j)),
            pl.BlockSpec((bm, bn),
                         lambda i, j, p, kt, ii, jj, we: (i, j)),
        ],
    )
    hi, lo = pl.pallas_call(
        functools.partial(_split_gemm_kernel_fused,
                          num_splits=num_splits, slice_bits=slice_bits),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        interpret=interpret,
    )(ii, jj, wexp, a_hi, a_lo, b_hi, b_lo)
    return hi[:m, :n], lo[:m, :n]


@functools.partial(jax.jit, static_argnames=(
    "num_splits", "slice_bits", "block_m", "block_n", "block_k",
    "interpret"))
def split_gemm_pallas_v1(a_sl, b_sl, num_splits: int,
                         slice_bits: int = SLICE_BITS,
                         block_m: int = 128, block_n: int = 128,
                         block_k: int = 128, interpret: bool = False):
    """Legacy v1 kernel: gathers every slice pair into HBM first.

    Kept as the A/B reference for the v2 traffic claim (see
    ``tile_model.traffic``) and for bit-identity regression tests —
    same schedule, same TwoSum, so v1 == v2 exactly.  Do not use for
    new call sites: it stages s*(s+1)/2 pair copies in HBM.
    """
    _, m, k = a_sl.shape
    _, _, n = b_sl.shape
    ii, jj = pair_indices(num_splits)
    smax = num_splits - 1
    a_pairs = jnp.take(a_sl, jnp.asarray(ii), axis=0)
    b_pairs = jnp.take(b_sl, jnp.asarray(jj), axis=0)
    weights = jnp.asarray(
        np.ldexp(np.float32(1.0), (smax - (ii + jj)) * slice_bits))

    bm = _block(m, block_m, SUBLANE_INT8)
    bn = _block(n, block_n, LANE)
    bk = _block(k, block_k, LANE)
    a_pairs = _pad_to(_pad_to(a_pairs, bm, 1), bk, 2)
    b_pairs = _pad_to(_pad_to(b_pairs, bk, 1), bn, 2)
    mp, kp = a_pairs.shape[1:]
    np_ = b_pairs.shape[2]
    num_pairs = len(ii)
    grid = (mp // bm, np_ // bn, num_pairs, kp // bk)

    hi, lo = pl.pallas_call(
        _split_gemm_kernel_v1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda i, j, p, kt: (p, i, kt)),
            pl.BlockSpec((1, bk, bn), lambda i, j, p, kt: (p, kt, j)),
            pl.BlockSpec((1,), lambda i, j, p, kt: (p,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, p, kt: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, p, kt: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        interpret=interpret,
    )(a_pairs, b_pairs, weights)
    return hi[:m, :n], lo[:m, :n]


def ozaki_matmul(a, b, num_splits: int = 6, accumulator: str = "df32",
                 out_dtype=None, slice_bits: int = SLICE_BITS,
                 interpret: bool = False, block_m: int | None = None,
                 block_n: int | None = None, block_k: int | None = None,
                 fuse_slicing: bool = False,
                 tiles: tile_model.TileDecision | None = None):
    """Pallas-backed drop-in for :func:`repro.core.ozaki.ozaki_matmul`.

    Same signature and semantics as the jnp reference path, plus
    ``interpret`` (run through the Pallas interpreter — required on
    CPU), tile-size overrides, ``fuse_slicing`` (quantize in VMEM, no
    slices in HBM) and ``tiles`` (a precomputed
    :class:`~repro.kernels.tile_model.TileDecision`).  When neither
    explicit blocks nor ``tiles`` are given, the analytic tile model
    picks the blocks — no autotuning sweep.

    The kernel's compensated-f32 accumulation *is* the reference
    ``"df32"`` accumulator; any other value raises ``ValueError``
    rather than silently computing something else (``None`` is
    accepted as "backend default").
    """
    if accumulator not in ("df32", None):
        raise ValueError(
            f"unsupported accumulator {accumulator!r} for the Pallas "
            "kernel: it always accumulates compensated-f32 ('df32'); "
            "pass 'df32' or None, or use repro.core.ozaki_matmul for "
            "'f64'")
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError("ozaki_matmul expects 2-D operands, got "
                         f"{a.shape} @ {b.shape}")
    if out_dtype is None:
        out_dtype = jnp.result_type(a.dtype, b.dtype)
    out_dtype = jnp.dtype(out_dtype)
    if jnp.issubdtype(out_dtype, jnp.complexfloating):
        raise NotImplementedError(
            "complex operands: route through repro.core.ozaki_matmul")

    m, k = a.shape
    n = b.shape[1]
    if tiles is None and None in (block_m, block_n, block_k):
        tiles = tile_model.select_tiles(m, k, n, num_splits,
                                        dtype=out_dtype,
                                        fused=fuse_slicing)
    if tiles is not None:
        block_m = tiles.block_m if block_m is None else block_m
        block_n = tiles.block_n if block_n is None else block_n
        block_k = tiles.block_k if block_k is None else block_k

    if fuse_slicing:
        a_hi, a_lo, sigma_a = slicing.to_operand_pair(a, axis=1)
        b_hi, b_lo, sigma_b = slicing.to_operand_pair(b, axis=0)
        hi, lo = split_gemm_pallas_fused(
            a_hi, a_lo, b_hi, b_lo, num_splits, slice_bits=slice_bits,
            block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=interpret)
    else:
        a_sl, sigma_a = slice_matrix(a, num_splits, axis=1,
                                     slice_bits=slice_bits)
        b_sl, sigma_b = slice_matrix(b, num_splits, axis=0,
                                     slice_bits=slice_bits)
        hi, lo = split_gemm_pallas(a_sl, b_sl, num_splits,
                                   slice_bits=slice_bits,
                                   block_m=block_m, block_n=block_n,
                                   block_k=block_k, interpret=interpret)
    deferred = 2.0 ** (-slice_bits * (num_splits + 1))
    c = (hi.astype(out_dtype) + lo.astype(out_dtype)) * deferred
    scale = (sigma_a[:, None] * sigma_b[None, :]).astype(out_dtype)
    return c * scale
