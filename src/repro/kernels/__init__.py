"""repro.kernels — Pallas kernels for the emulation engine."""

from . import ops

__all__ = ["ops"]
