"""repro.kernels — Pallas kernels, slicing primitives, tile model.

``tile_model`` and ``slicing`` are plain jnp/numpy and import eagerly;
``ops`` (the Pallas kernels) loads lazily so hosts without
``jax.experimental.pallas`` can still consult the analytic tile model
(the tuner and the offload interceptor do).
"""

from . import slicing, tile_model

__all__ = ["ops", "slicing", "tile_model"]


def __getattr__(name):
    if name == "ops":
        import importlib
        module = importlib.import_module(".ops", __name__)
        globals()["ops"] = module
        return module
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
