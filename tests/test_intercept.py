"""Automatic offload: site discovery and numerical agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PrecisionPolicy, offload, site_report


def _solver(a, b):
    x = jnp.tanh(a @ b)
    for _ in range(2):
        x = x @ b / jnp.linalg.norm(x)
    return jnp.sum(x)


@pytest.fixture(scope="module")
def operands():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((192, 192)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((192, 192)), jnp.float32)
    return a, b


class TestSiteReport:
    def test_discovers_all_matmuls(self, operands):
        a, b = operands
        sites = site_report(_solver, PrecisionPolicy(min_dim=128))(a, b)
        assert len(sites) == 3
        assert all(s.offloaded for s in sites)
        assert [s.name for s in sites] == ["dot0", "dot1", "dot2"]
        assert sites[0].lhs_shape == (192, 192)

    def test_min_dim_gates_sites(self, operands):
        a, b = operands
        sites = site_report(_solver, PrecisionPolicy(min_dim=256))(a, b)
        assert all(not s.offloaded for s in sites)
        assert "min_dim" in sites[0].reason

    def test_small_dims_reported_not_offloaded(self):
        def f(a, b):
            return (a @ b) @ b.T  # k=8 below any sane min_dim

        a = jnp.ones((256, 8))
        b = jnp.ones((8, 256))
        sites = site_report(f, PrecisionPolicy(min_dim=64))(a, b)
        assert [s.offloaded for s in sites] == [False, False]

    def test_site_splits_override(self, operands):
        a, b = operands
        pol = PrecisionPolicy(default_splits=4, min_dim=64,
                              site_splits={"dot1": 9})
        sites = site_report(_solver, pol)(a, b)
        assert [s.splits for s in sites] == [4, 9, 4]


class TestOffloadNumerics:
    def test_agrees_with_native(self, operands):
        a, b = operands
        pol = PrecisionPolicy(default_splits=7, min_dim=128)
        ref = float(_solver(a, b))
        got = float(offload(_solver, pol)(a, b))
        assert abs(got - ref) / abs(ref) < 1e-5

    def test_composes_with_jit(self, operands):
        a, b = operands
        pol = PrecisionPolicy(default_splits=6, min_dim=128)
        eager = offload(_solver, pol)(a, b)
        jitted = jax.jit(offload(_solver, pol))(a, b)
        np.testing.assert_allclose(np.asarray(jitted),
                                   np.asarray(eager), rtol=1e-6)

    def test_gated_function_is_bit_identical(self, operands):
        # min_dim above every site => the interpreter must reproduce
        # the native computation exactly (same primitives, same order).
        a, b = operands
        pol = PrecisionPolicy(min_dim=4096)
        ref = _solver(a, b)
        got = offload(_solver, pol)(a, b)
        assert float(ref) == float(got)

    def test_pytree_outputs_and_kwargs(self):
        def f(a, scale=2.0):
            return {"y": (a @ a) * scale, "trace": jnp.trace(a)}

        a = jnp.asarray(np.random.default_rng(1)
                        .standard_normal((160, 160)), jnp.float32)
        pol = PrecisionPolicy(default_splits=7, min_dim=64)
        ref = f(a, scale=3.0)
        got = offload(f, pol)(a, scale=3.0)
        assert set(got) == {"y", "trace"}
        np.testing.assert_allclose(np.asarray(got["y"]),
                                   np.asarray(ref["y"]), rtol=1e-4,
                                   atol=1e-3)
        assert float(got["trace"]) == float(ref["trace"])

    def test_transposed_contraction(self):
        def f(a, b):
            return jax.lax.dot_general(
                a, b, dimension_numbers=(((0,), (1,)), ((), ())))

        rng = np.random.default_rng(2)
        a = jnp.asarray(rng.standard_normal((128, 96)))
        b = jnp.asarray(rng.standard_normal((144, 128)))
        pol = PrecisionPolicy(default_splits=9, min_dim=64,
                              accumulator="f64")
        ref = np.asarray(f(a, b))
        got = np.asarray(offload(f, pol)(a, b))
        np.testing.assert_allclose(got, ref, rtol=1e-10, atol=1e-10)
