"""Automatic offload: site discovery and numerical agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PrecisionPolicy, estimate_rel_error, offload,
                        site_report, transform_jaxpr)


def _solver(a, b):
    x = jnp.tanh(a @ b)
    for _ in range(2):
        x = x @ b / jnp.linalg.norm(x)
    return jnp.sum(x)


@pytest.fixture(scope="module")
def operands():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((192, 192)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((192, 192)), jnp.float32)
    return a, b


class TestSiteReport:
    def test_discovers_all_matmuls(self, operands):
        a, b = operands
        sites = site_report(_solver, PrecisionPolicy(min_dim=128))(a, b)
        assert len(sites) == 3
        assert all(s.offloaded for s in sites)
        assert [s.name for s in sites] == ["dot0", "dot1", "dot2"]
        assert sites[0].lhs_shape == (192, 192)

    def test_min_dim_gates_sites(self, operands):
        a, b = operands
        sites = site_report(_solver, PrecisionPolicy(min_dim=256))(a, b)
        assert all(not s.offloaded for s in sites)
        assert "min_dim" in sites[0].reason

    def test_small_dims_reported_not_offloaded(self):
        def f(a, b):
            return (a @ b) @ b.T  # k=8 below any sane min_dim

        a = jnp.ones((256, 8))
        b = jnp.ones((8, 256))
        sites = site_report(f, PrecisionPolicy(min_dim=64))(a, b)
        assert [s.offloaded for s in sites] == [False, False]

    def test_site_splits_override(self, operands):
        a, b = operands
        pol = PrecisionPolicy(default_splits=4, min_dim=64,
                              site_splits={"dot1": 9})
        sites = site_report(_solver, pol)(a, b)
        assert [s.splits for s in sites] == [4, 9, 4]

    def test_pallas_sites_carry_tile_choice(self, operands):
        # Pallas-family sites record the analytic tile model's block
        # pick (and show it in repr); jnp-family sites record None.
        a, b = operands
        pol = PrecisionPolicy(backend="pallas_int8", default_splits=4,
                              min_dim=64)
        sites = site_report(_solver, pol)(a, b)
        for s in sites:
            assert set(s.tiles) == {"block_m", "block_n", "block_k",
                                    "pairs", "schedule"}
            assert s.tiles["schedule"] == "ordered"
            assert "tiles=" in repr(s)
        jnp_sites = site_report(_solver,
                                PrecisionPolicy(min_dim=64))(a, b)
        assert all(s.tiles is None for s in jnp_sites)


class TestOffloadNumerics:
    def test_agrees_with_native(self, operands):
        a, b = operands
        pol = PrecisionPolicy(default_splits=7, min_dim=128)
        ref = float(_solver(a, b))
        got = float(offload(_solver, pol)(a, b))
        assert abs(got - ref) / abs(ref) < 1e-5

    def test_composes_with_jit(self, operands):
        a, b = operands
        pol = PrecisionPolicy(default_splits=6, min_dim=128)
        eager = offload(_solver, pol)(a, b)
        jitted = jax.jit(offload(_solver, pol))(a, b)
        np.testing.assert_allclose(np.asarray(jitted),
                                   np.asarray(eager), rtol=1e-6)

    def test_gated_function_is_bit_identical(self, operands):
        # min_dim above every site => the interpreter must reproduce
        # the native computation exactly (same primitives, same order).
        a, b = operands
        pol = PrecisionPolicy(min_dim=4096)
        ref = _solver(a, b)
        got = offload(_solver, pol)(a, b)
        assert float(ref) == float(got)

    def test_pytree_outputs_and_kwargs(self):
        def f(a, scale=2.0):
            return {"y": (a @ a) * scale, "trace": jnp.trace(a)}

        a = jnp.asarray(np.random.default_rng(1)
                        .standard_normal((160, 160)), jnp.float32)
        pol = PrecisionPolicy(default_splits=7, min_dim=64)
        ref = f(a, scale=3.0)
        got = offload(f, pol)(a, scale=3.0)
        assert set(got) == {"y", "trace"}
        np.testing.assert_allclose(np.asarray(got["y"]),
                                   np.asarray(ref["y"]), rtol=1e-4,
                                   atol=1e-3)
        assert float(got["trace"]) == float(ref["trace"])

    def test_transposed_contraction(self):
        def f(a, b):
            return jax.lax.dot_general(
                a, b, dimension_numbers=(((0,), (1,)), ((), ())))

        rng = np.random.default_rng(2)
        a = jnp.asarray(rng.standard_normal((128, 96)))
        b = jnp.asarray(rng.standard_normal((144, 128)))
        pol = PrecisionPolicy(default_splits=9, min_dim=64,
                              accumulator="f64")
        ref = np.asarray(f(a, b))
        got = np.asarray(offload(f, pol)(a, b))
        np.testing.assert_allclose(got, ref, rtol=1e-10, atol=1e-10)


class TestTransformCacheLRU:
    def test_cache_info_counts(self, operands):
        a, b = operands
        pol = PrecisionPolicy(default_splits=4, min_dim=64)
        w = offload(_solver, pol)
        assert w.cache_info() == (0, 0, 64, 0)
        w(a, b)
        w(a, b)
        w(a[:96], b)
        info = w.cache_info()
        assert (info.hits, info.misses, info.currsize) == (1, 2, 2)
        assert info.maxsize == 64
        w.cache_clear()
        assert w.cache_info() == (0, 0, 64, 0)

    def test_signature_churn_is_bounded(self, operands):
        # Serve-style churn: every padded batch size is a new
        # signature; the cache must evict, not grow without bound.
        _, b = operands

        def f(a, b):
            return a @ b

        w = offload(f, PrecisionPolicy(min_dim=64), cache_size=4)
        for rows in range(64, 64 + 10):
            w(jnp.ones((rows, 192)), b)
        info = w.cache_info()
        assert info.currsize == 4 and info.misses == 10

    def test_eviction_is_least_recently_used(self, operands):
        _, b = operands

        def f(a, b):
            return a @ b

        w = offload(f, PrecisionPolicy(min_dim=64), cache_size=2)
        a64, a80, a96 = (jnp.ones((r, 192)) for r in (64, 80, 96))
        w(a64, b)
        w(a80, b)
        w(a64, b)   # refresh a64: a80 is now the LRU entry
        w(a96, b)   # evicts a80
        assert w.cache_info().currsize == 2
        w(a64, b)   # still cached
        assert w.cache_info().hits == 2
        w(a80, b)   # was evicted -> re-traces
        assert w.cache_info().misses == 4

    def test_rejects_senseless_cache_size(self):
        with pytest.raises(ValueError, match="cache_size"):
            offload(lambda x: x, cache_size=0)


class TestSharedSiteNames:
    def test_nested_pjit_names_identical(self, operands):
        # Regression: PR-1 numbered sites differently in site_report
        # (prefix+len) and offload (flat counter).  The shared walker
        # must yield identical names for nested-pjit functions.
        a, b = operands

        @jax.jit
        def inner(x, y):
            return x @ y

        def f(a, b):
            u = inner(a, b)          # inside a pjit body
            v = jnp.tanh(a) @ u      # top level
            return jnp.sum(inner(v, b))  # second pjit body

        pol = PrecisionPolicy(default_splits=5, min_dim=64)
        report_names = [s.name for s in site_report(f, pol)(a, b)]
        offload_names = [s.name for s in offload(f, pol).sites(a, b)]
        assert report_names == offload_names
        assert report_names == ["dot0", "dot1", "dot2"]

    def test_control_flow_names_are_path_scoped(self, operands):
        a, b = operands

        def f(a, b):
            def body(c, x):
                return c @ x, jnp.sum(c)
            c, sums = jax.lax.scan(body, a, jnp.stack([b, b]))
            return jnp.sum(c @ b) + jnp.sum(sums)

        pol = PrecisionPolicy(default_splits=5, min_dim=64)
        report_names = [s.name for s in site_report(f, pol)(a, b)]
        offload_names = [s.name for s in offload(f, pol).sites(a, b)]
        assert report_names == offload_names
        assert report_names == ["scan0/dot0", "dot0"]

    def test_offload_of_jitted_fn_names_identical(self, operands):
        # offload(jax.jit(f)): the whole function arrives as one pjit
        # eqn; inlining must keep the flat dot numbering of f itself.
        a, b = operands
        f = jax.jit(_solver)
        pol = PrecisionPolicy(default_splits=5, min_dim=64)
        report_names = [s.name for s in site_report(f, pol)(a, b)]
        offload_names = [s.name for s in offload(f, pol).sites(a, b)]
        assert report_names == offload_names
        assert report_names == ["dot0", "dot1", "dot2"]

    def test_vmap_of_offload_names_identical(self, operands):
        # jax.vmap(offload(f)) traces the wrapper with batch tracers:
        # sites must be discovered on the *per-example* shapes with the
        # same names an unbatched call produces, and execution must
        # match vmap of the native function.
        a, b = operands
        pol = PrecisionPolicy(default_splits=8, min_dim=64)
        wrapped = offload(_solver, pol)
        batched = jax.vmap(wrapped, in_axes=(0, None))
        stack = jnp.stack([a, 2.0 * a, a - 1.0])
        got = np.asarray(batched(stack, b))
        ref = np.asarray(jax.vmap(_solver, in_axes=(0, None))(stack, b))
        np.testing.assert_allclose(got, ref, rtol=1e-5)
        # The signature seen under vmap is the per-example one: names
        # (and decisions) are identical to the unbatched report.
        assert [s.name for s in wrapped.sites(a, b)] == \
            [s.name for s in site_report(_solver, pol)(a, b)]

    def test_site_override_applies_through_offload(self, operands):
        # The stable names must be usable PrecisionPolicy.site_splits
        # keys: overriding one site changes only that site's splits.
        a, b = operands
        pol = PrecisionPolicy(default_splits=4, min_dim=64,
                              site_splits={"dot1": 9})
        sites = offload(_solver, pol).sites(a, b)
        assert [s.splits for s in sites] == [4, 9, 4]


class TestBatchedOffload:
    def test_rank3_batched_dot_general(self):
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.standard_normal((4, 160, 160)))
        y = jnp.asarray(rng.standard_normal((4, 160, 160)))

        def f(x, y):
            return jnp.einsum("bij,bjk->bik", x, y)

        pol = PrecisionPolicy(default_splits=8, min_dim=128)
        sites = offload(f, pol).sites(x, y)
        assert len(sites) == 1 and sites[0].offloaded
        ref = np.asarray(f(x, y))
        got = np.asarray(offload(f, pol)(x, y))
        denom = np.asarray(jnp.einsum("bij,bjk->bik", jnp.abs(x),
                                      jnp.abs(y)))
        tol = estimate_rel_error(8, 160)
        assert np.max(np.abs(got - ref) / denom) < tol

    def test_batch_dims_not_counted_toward_min_dim(self):
        x = jnp.ones((256, 32, 32))
        y = jnp.ones((256, 32, 32))
        sites = site_report(
            lambda x, y: jnp.einsum("bij,bjk->bik", x, y),
            PrecisionPolicy(min_dim=128))(x, y)
        assert [s.offloaded for s in sites] == [False]
        assert "min_dim" in sites[0].reason

    def test_rank4_free_dims_merge(self):
        rng = np.random.default_rng(6)
        x = jnp.asarray(rng.standard_normal((12, 12, 144)))
        y = jnp.asarray(rng.standard_normal((144, 144)))

        def f(x, y):  # (12*12, 144) @ (144, 144) after merging
            return jnp.tensordot(x, y, axes=([2], [0]))

        pol = PrecisionPolicy(default_splits=8, min_dim=128)
        sites = offload(f, pol).sites(x, y)
        assert len(sites) == 1 and sites[0].offloaded
        ref = np.asarray(f(x, y))
        got = np.asarray(offload(f, pol)(x, y))
        np.testing.assert_allclose(got, ref, rtol=0,
                                   atol=estimate_rel_error(8, 144)
                                   * np.max(np.abs(ref)))


class TestControlFlowOffload:
    def test_scan_body_offloaded(self):
        rng = np.random.default_rng(7)
        c0 = jnp.asarray(rng.standard_normal((144, 144)))
        xs = jnp.asarray(rng.standard_normal((3, 144, 144)))

        def f(c0, xs):
            def body(c, x):
                return jnp.tanh(c @ x), jnp.trace(c)
            return jax.lax.scan(body, c0, xs)

        pol = PrecisionPolicy(default_splits=8, min_dim=128)
        sites = offload(f, pol).sites(c0, xs)
        assert [s.name for s in sites] == ["scan0/dot0"]
        assert sites[0].offloaded
        ref_c, ref_t = f(c0, xs)
        got_c, got_t = offload(f, pol)(c0, xs)
        np.testing.assert_allclose(np.asarray(got_c), np.asarray(ref_c),
                                   rtol=0, atol=1e-9)
        np.testing.assert_allclose(np.asarray(got_t), np.asarray(ref_t),
                                   rtol=1e-9)

    def test_cond_branches_offloaded(self):
        rng = np.random.default_rng(8)
        a = jnp.asarray(rng.standard_normal((144, 144)))

        def f(pred, a):
            return jax.lax.cond(pred, lambda x: x @ x,
                                lambda x: x + 1.0, a)

        pol = PrecisionPolicy(default_splits=8, min_dim=128)
        wrapped = offload(f, pol)
        names = [s.name for s in wrapped.sites(True, a)]
        assert names == ["cond0/br1/dot0"] or names == ["cond0/br0/dot0"]
        for pred in (True, False):
            ref = np.asarray(f(pred, a))
            got = np.asarray(wrapped(pred, a))
            np.testing.assert_allclose(got, ref, rtol=0, atol=1e-9)

    def test_while_body_offloaded(self):
        rng = np.random.default_rng(9)
        a = jnp.asarray(0.01 * rng.standard_normal((144, 144)))

        def f(a):
            def body(v):
                i, x = v
                return i + 1, x @ x
            def cond(v):
                return v[0] < 3
            return jax.lax.while_loop(cond, body, (0, a))[1]

        pol = PrecisionPolicy(default_splits=9, min_dim=128)
        wrapped = offload(f, pol)
        assert [s.name for s in wrapped.sites(a)] == ["while0/dot0"]
        ref = np.asarray(f(a))
        got = np.asarray(wrapped(a))
        np.testing.assert_allclose(got, ref, rtol=0,
                                   atol=1e-10 * max(1.0,
                                                    np.max(np.abs(ref))))


class TestOffloadAutodiff:
    def test_grad_through_offload(self, operands):
        a, b = operands

        def f(a, b):
            return jnp.sum(jnp.tanh(a @ b))

        pol = PrecisionPolicy(default_splits=8, min_dim=64)
        g_ref = np.asarray(jax.grad(f)(a, b))
        g_off = np.asarray(jax.grad(offload(f, pol))(a, b))
        assert np.max(np.abs(g_off - g_ref)) < 1e-3
        assert np.max(np.abs(g_off - g_ref)) / np.max(np.abs(g_ref)) \
            < 1e-2

    def test_grad_is_also_emulated(self, operands):
        # The backward pass must route through the backend too: with a
        # very low split count the gradient error is visibly larger
        # than with a high one (pure-native backward would show no
        # dependence on the split count).
        a, b = operands

        def f(a, b):
            return jnp.sum((a @ b) ** 2)

        def gerr(splits):
            pol = PrecisionPolicy(default_splits=splits, min_dim=64)
            g = np.asarray(jax.grad(offload(f, pol))(a, b))
            g_ref = np.asarray(jax.grad(f)(a, b))
            return np.max(np.abs(g - g_ref))

        assert gerr(2) > 10 * gerr(6)


class TestTransformJaxpr:
    def test_no_per_call_retracing(self, operands):
        # offload must trace fn exactly once per input signature.
        a, b = operands
        calls = [0]

        def f(a, b):
            calls[0] += 1
            return jnp.sum(a @ b)

        pol = PrecisionPolicy(default_splits=4, min_dim=64)
        wrapped = offload(f, pol)
        wrapped(a, b)
        wrapped(a, b)
        wrapped(a, b)
        assert calls[0] == 1
        wrapped(a[:96], b)  # new signature -> one more trace
        assert calls[0] == 2

    def test_transform_is_jaxpr_to_jaxpr(self, operands):
        a, b = operands
        pol = PrecisionPolicy(default_splits=5, min_dim=64)
        closed = jax.make_jaxpr(_solver)(a, b)
        transformed, sites = transform_jaxpr(closed, pol)
        assert type(transformed) is type(closed)
        assert len([s for s in sites if s.offloaded]) == 3
        # The rewritten program must contain no bare dot_general at the
        # top level: every site now lives inside its custom_vjp wrapper.
        top = [e.primitive.name for e in transformed.jaxpr.eqns]
        assert "dot_general" not in top
        out = jax.core.eval_jaxpr(transformed.jaxpr, transformed.consts,
                                  a, b)
        ref = float(_solver(a, b))
        assert abs(float(out[0]) - ref) / abs(ref) < 1e-3


class TestCallPrimitiveBoundaries:
    def test_remat_body_is_offloaded(self, operands):
        # jax.checkpoint stages through the 'remat2' primitive: its
        # body must be inlined and its matmuls rewritten (regression:
        # a stale primitive-name set silently skipped remat bodies).
        a, b = operands

        def f(a, b):
            return jnp.sum(jax.checkpoint(lambda x, y: x @ y)(a, b))

        pol = PrecisionPolicy(default_splits=3, min_dim=64)
        wrapped = offload(f, pol)
        sites = wrapped.sites(a, b)
        assert [s.name for s in sites] == ["dot0"]
        assert sites[0].offloaded
        # s=3 is coarse enough that emulation must visibly differ.
        assert float(wrapped(a, b)) != float(f(a, b))
        g_ref = np.asarray(jax.grad(f)(a, b))
        g_off = np.asarray(jax.grad(wrapped)(a, b))
        assert np.max(np.abs(g_off - g_ref)) < 1e-1

    def test_custom_jvp_rule_preserved(self, operands):
        # Custom-derivative functions are opaque: offload must not
        # replace the user's jvp rule by differentiating an inlined
        # primal (regression: inlining gave nonzero grad here).
        a, b = operands

        @jax.custom_jvp
        def gmat(x, y):
            return x @ y

        @gmat.defjvp
        def gmat_jvp(primals, tangents):
            x, y = primals
            return x @ y, jnp.zeros((x.shape[0], y.shape[1]),
                                    x.dtype)

        def f(a, b):
            return jnp.sum(gmat(a, b))

        pol = PrecisionPolicy(default_splits=3, min_dim=64)
        wrapped = offload(f, pol)
        assert wrapped.sites(a, b) == []  # opaque: no sites inside
        assert float(wrapped(a, b)) == float(f(a, b))
        g = np.asarray(jax.grad(wrapped)(a, b))
        assert np.max(np.abs(g)) == 0.0  # the zero-tangent rule held

    def test_custom_vjp_rule_preserved(self, operands):
        a, b = operands

        @jax.custom_vjp
        def vmat(x, y):
            return x @ y

        def vfwd(x, y):
            return x @ y, (x, y)

        def vbwd(res, g):
            x, y = res
            return jnp.zeros_like(x), jnp.zeros_like(y)

        vmat.defvjp(vfwd, vbwd)

        def f(a, b):
            return jnp.sum(vmat(a, b))

        pol = PrecisionPolicy(default_splits=3, min_dim=64)
        wrapped = offload(f, pol)
        assert float(wrapped(a, b)) == float(f(a, b))
        assert float(jax.jit(wrapped)(a, b)) == float(f(a, b))
        g = np.asarray(jax.grad(wrapped)(a, b))
        assert np.max(np.abs(g)) == 0.0

    def test_shared_inner_jaxpr_sites_stay_distinct(self, operands):
        # JAX's tracing cache reuses one body jaxpr object (and thus
        # the same eqn objects) for every call of a jit-ed inner
        # function.  Decisions must key on the structural name, not on
        # equation identity, or a site_splits override for dot0 is
        # silently applied from dot1's decision (regression).
        a, b = operands
        inner = jax.jit(lambda x, y: x @ y)

        def f(a, b):
            return jnp.sum(inner(a, b)) + jnp.sum(inner(b, a))

        base = PrecisionPolicy(default_splits=3, min_dim=64)
        tuned = PrecisionPolicy(default_splits=3, min_dim=64,
                                site_splits={"dot0": 9})
        assert [s.splits for s in offload(f, tuned).sites(a, b)] == [9, 3]
        # The override must change execution, not just the report.
        assert float(offload(f, tuned)(a, b)) != \
            float(offload(f, base)(a, b))
        # And with both sites pinned high, the result tracks native.
        both = PrecisionPolicy(default_splits=8, min_dim=64)
        ref = float(f(a, b))
        assert abs(float(offload(f, both)(a, b)) - ref) / abs(ref) < 1e-5
