"""Accuracy ladder and arithmetic invariants of the Ozaki engine."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (num_pair_gemms, ozaki_matmul, pair_indices,
                        slice_matrix)


def _gauss(m, k, seed, dtype=np.float64):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((m, k)).astype(dtype))


def _max_rel(c, ref, a, b):
    denom = jnp.abs(a) @ jnp.abs(b)
    return float(jnp.max(jnp.abs(c - ref) / denom))


class TestAccuracyLadder:
    @pytest.mark.parametrize("accumulator", ["df32", "f64"])
    def test_monotone_and_hits_1e12_by_s9(self, accumulator):
        a, b = _gauss(256, 256, 0), _gauss(256, 256, 1)
        ref = a @ b
        errs = []
        for s in range(3, 10):
            c = ozaki_matmul(a, b, num_splits=s, accumulator=accumulator,
                             out_dtype=jnp.float64)
            errs.append(_max_rel(c, ref, a, b))
        assert errs[-1] < 1e-12, errs
        for lo, hi in zip(errs[1:], errs[:-1]):
            assert lo < hi, f"ladder not monotone: {errs}"

    def test_more_slice_bits_more_accuracy(self):
        a, b = _gauss(128, 128, 2), _gauss(128, 128, 3)
        ref = a @ b
        e6 = _max_rel(ozaki_matmul(a, b, 4, slice_bits=6,
                                   out_dtype=jnp.float64), ref, a, b)
        e7 = _max_rel(ozaki_matmul(a, b, 4, slice_bits=7,
                                   out_dtype=jnp.float64), ref, a, b)
        assert e7 < e6

    def test_extreme_row_scales(self):
        # Per-row/col power-of-two scaling must absorb wild dynamic
        # range without overflowing the int8 slices.
        a = _gauss(64, 64, 4) * jnp.logspace(-12, 12, 64)[:, None]
        b = _gauss(64, 64, 5) * jnp.logspace(8, -8, 64)[None, :]
        ref = a @ b
        c = ozaki_matmul(a, b, num_splits=9, accumulator="f64",
                         out_dtype=jnp.float64)
        assert _max_rel(c, ref, a, b) < 1e-12


class TestSlicing:
    def test_reconstruction_is_exact_up_to_truncation(self):
        x = _gauss(32, 48, 6)
        s, w = 5, 6
        slices, sigma = slice_matrix(x, s, axis=1, slice_bits=w)
        assert slices.shape == (s, 32, 48)
        assert slices.dtype == jnp.int8
        recon = sum(
            slices[t].astype(jnp.float64) * 2.0 ** (-w * (t + 1))
            for t in range(s))
        resid = jnp.abs(x / sigma[:, None] - recon)
        assert float(jnp.max(resid)) <= 2.0 ** (-w * s - 1)

    def test_sigma_is_power_of_two(self):
        x = _gauss(16, 16, 7) * 3.7e-5
        _, sigma = slice_matrix(x, 3, axis=1)
        frac, _ = np.frexp(np.asarray(sigma))
        assert np.all(frac == 0.5)  # exact powers of two

    def test_pair_count(self):
        for s in range(1, 10):
            ii, jj = pair_indices(s)
            assert len(ii) == num_pair_gemms(s) == s * (s + 1) // 2
            assert np.all(ii + jj < s)


class TestDtypesAndShapes:
    def test_f32_inputs_default_out(self):
        a, b = _gauss(96, 64, 8, np.float32), _gauss(64, 80, 9, np.float32)
        c = ozaki_matmul(a, b, num_splits=6)
        assert c.dtype == jnp.float32
        assert c.shape == (96, 80)
        ref = a.astype(jnp.float64) @ b.astype(jnp.float64)
        assert _max_rel(c.astype(jnp.float64), ref, a, b) < 1e-6

    def test_complex128(self):
        rng = np.random.default_rng(10)
        a = jnp.asarray(rng.standard_normal((64, 64))
                        + 1j * rng.standard_normal((64, 64)))
        b = jnp.asarray(rng.standard_normal((64, 64))
                        + 1j * rng.standard_normal((64, 64)))
        c = ozaki_matmul(a, b, num_splits=9, accumulator="f64")
        assert c.dtype == jnp.complex128
        ref = a @ b
        rel = float(jnp.max(jnp.abs(c - ref)) / jnp.max(jnp.abs(ref)))
        assert rel < 1e-12

    def test_rejects_bad_rank_and_splits(self):
        a = _gauss(8, 8, 11)
        with pytest.raises(ValueError):
            ozaki_matmul(a.reshape(2, 4, 8), a)
        with pytest.raises(ValueError):
            ozaki_matmul(a, a, num_splits=0)
        with pytest.raises(ValueError):
            ozaki_matmul(a, a, accumulator="f16")
