"""Analytic tile-cost model: hand-computed figures + properties.

The model is closed-form (no autotuning), so the unit tests pin its
numbers against figures computed by hand from the documented formulas,
and a property sweep checks every pick is admissible (fits the VMEM
budget, MXU-aligned).  No Pallas import anywhere — the model must work
on hosts without a Pallas build.
"""

import numpy as np
import pytest

from repro.core.ozaki import num_pair_gemms, pair_indices
from repro.kernels import tile_model as tm


class TestHandComputedFigures:
    def test_vmem_bytes_presliced(self):
        # 2 * (bm*bk + bk*bn) int8 double-buffered inputs
        # + 2 * 4 * bm*bn f32 hi/lo accumulators.
        assert tm.vmem_bytes(128, 128, 128) == \
            2 * (128 * 128 + 128 * 128) + 2 * 4 * 128 * 128 == 196608
        assert tm.vmem_bytes(32, 128, 256) == \
            2 * (32 * 256 + 256 * 128) + 2 * 4 * 32 * 128

    def test_vmem_bytes_fused(self):
        # Fused streams f32 hi+lo halves (8 B/elem) and adds int8
        # slice scratch for the quantized tiles.
        e = 128 * 128 + 128 * 128
        assert tm.vmem_bytes(128, 128, 128, fused=True) == \
            2 * 8 * e + e + 2 * 4 * 128 * 128 == 688128

    def test_mxu_tile_cycles(self):
        # One 128^3 MAC block per 128 cycles on the 128x128 array.
        assert tm.mxu_tile_cycles(128, 128, 128) == 128
        assert tm.mxu_tile_cycles(256, 512, 128) == 2 * 4 * 1 * 128
        # Sub-array blocks still occupy a full pass.
        assert tm.mxu_tile_cycles(32, 128, 128) == 128

    def test_hbm_bytes_per_step(self):
        assert tm.hbm_bytes_per_step(128, 128, 128) == 32768
        assert tm.hbm_bytes_per_step(128, 128, 128, fused=True) == \
            8 * 32768

    def test_select_128_cube_s6(self):
        # The worked example in the module docstring: at 128^3 the only
        # aligned candidates are bm in {32, 64, 128} x bn=bk=128, and
        # the full 128^3 block wins on cycles-per-flop.
        d = tm.select_tiles(128, 128, 128, 6, dtype="float32")
        assert (d.block_m, d.block_n, d.block_k) == (128, 128, 128)
        assert d.vmem_bytes == 196608
        assert d.mxu_cycles_step == 128
        assert d.pairs == 21
        assert d.kernel_invocations == 21  # 1 * 1 * 21 pairs * 1
        assert d.schedule == "ordered"

    def test_traffic_figures_128_cube_s6(self):
        # elems = 128*128 + 128*128 = 32768 per slice layer (A + B).
        t = tm.traffic(128, 128, 128, 6, 128, 128, 128)
        assert t.slice_read_bytes_v1 == 21 * 32768 == 688128
        assert t.slice_read_bytes_v2 == 6 * 32768 == 196608
        assert t.read_reduction == pytest.approx(3.5)
        assert t.stream_bytes == 21 * 32768  # 21 grid steps
        assert t.out_bytes == 2 * 4 * 128 * 128
        assert t.total_v1 > t.total_v2

    def test_read_reduction_is_s_plus_1_over_2(self):
        for s in range(3, 10):
            t = tm.traffic(256, 256, 256, s, 128, 128, 128)
            assert t.read_reduction == pytest.approx((s + 1) / 2)

    def test_split_cost_figures(self):
        # pairs(s) + s * tax, tax = macs_per_cycle * (2/1024) / B-per-cyc.
        p = tm.DEFAULT_PARAMS
        tax = p.macs_per_cycle * (2.0 / 1024) / p.bytes_per_cycle
        assert tm.split_cost(6) == pytest.approx(21 + 6 * tax)
        assert tm.split_cost(1) == pytest.approx(1 + tax)

    def test_canonical_selection_has_no_shape_totals(self):
        # Canonical picks (m/n unknown) must not carry shape-dependent
        # totals — they'd leak per-shard geometry into plans.
        d = tm.select_tiles(None, 96, None, 4, dtype="float32")
        assert d.kernel_invocations is None
        assert d.traffic_model is None
        # k=96 caps block_k at align_up(96, 128) = 128.
        assert d.block_k == 128


class TestSelectionProperties:
    @pytest.mark.parametrize("fused", [False, True])
    def test_every_pick_fits_vmem_and_alignment(self, fused):
        rng = np.random.default_rng(0)
        for _ in range(40):
            m, k, n = (int(rng.integers(1, 2048)) for _ in range(3))
            s = int(rng.integers(1, 10))
            d = tm.select_tiles(m, k, n, s, fused=fused)
            assert d.vmem_bytes <= tm.DEFAULT_PARAMS.vmem_budget
            assert d.vmem_bytes == tm.vmem_bytes(
                d.block_m, d.block_n, d.block_k, fused=fused)
            assert d.block_m % tm.SUBLANE_INT8 == 0
            assert d.block_n % tm.LANE == 0
            assert d.block_k % tm.LANE == 0
            assert d.kernel_invocations >= d.pairs == num_pair_gemms(s)
            assert d.traffic_model.read_reduction == \
                pytest.approx((s + 1) / 2)

    def test_deterministic(self):
        a = tm.select_tiles(300, 700, 500, 6)
        b = tm.select_tiles(300, 700, 500, 6)
        assert a == b

    def test_explicit_none_dims_ignore_geometry(self):
        # The canonical pick depends on (k, splits, fused) only.
        d1 = tm.select_tiles(None, 4096, None, 6)
        d2 = tm.select_tiles(None, 4096, None, 6, dtype="float64")
        assert (d1.block_m, d1.block_n, d1.block_k) == \
            (d2.block_m, d2.block_n, d2.block_k)


class TestPairSchedule:
    def test_ordered_matches_reference(self):
        for s in (1, 3, 6, 9):
            ii, jj = tm.pair_schedule(s, "ordered")
            ri, rj = pair_indices(s)
            assert list(ii) == list(ri) and list(jj) == list(rj)

    def test_grouped_is_a_permutation(self):
        ii, jj = tm.pair_schedule(6, "grouped")
        ri, rj = pair_indices(6)
        assert sorted(zip(ii, jj)) == sorted(zip(ri, rj))
        # Grouped sorts by A-slice index for block reuse accounting.
        assert list(ii) == sorted(ii)

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError):
            tm.pair_schedule(6, "random")


class TestSplitCost:
    def test_strictly_monotone(self):
        costs = [tm.split_cost(s) for s in range(1, 12)]
        assert all(b > a for a, b in zip(costs, costs[1:]))

    def test_marginal_cost_grows(self):
        # Each extra split adds s+1 more pairs plus one slice tax, so
        # the marginal cost is itself increasing — the property the
        # tuner's greedy marginal analysis relies on.
        marg = [tm.split_cost(s + 1) - tm.split_cost(s)
                for s in range(1, 10)]
        assert all(b > a for a, b in zip(marg, marg[1:]))

    def test_dominated_by_pair_count(self):
        # The slice tax is a small correction, not the driver: v2 is
        # compute-bound (the paper's roofline argument).
        for s in range(1, 10):
            assert 0 < tm.split_cost(s) - num_pair_gemms(s) < 1.0
