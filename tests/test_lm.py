"""Model + config tests: shapes, causality, cache/forward agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LMConfig, get_config
from repro.models import Model

# A deliberately small config so each test runs in well under a second.
SMALL = LMConfig(name="test_small", vocab_size=128, num_layers=2,
                 d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                 d_ff=128, max_seq_len=64)


@pytest.fixture(scope="module")
def small_model():
    model = Model(SMALL)
    params = model.init_params(jax.random.PRNGKey(0))
    # Non-zero head so logits (and greedy choices) are token-dependent.
    params["lm_head"] = 0.1 * jax.random.normal(
        jax.random.PRNGKey(1), params["lm_head"].shape,
        dtype=jnp.float32)
    return model, params


def _tokens(rng, b, t, vocab=SMALL.vocab_size):
    return jnp.asarray(rng.integers(0, vocab, (b, t)), jnp.int32)


class TestConfig:
    def test_presets_resolve(self):
        cfg = get_config("smollm_360m")
        assert cfg.num_heads % cfg.num_kv_heads == 0
        assert get_config("tiny").num_layers == 2

    def test_unknown_preset_raises(self):
        with pytest.raises(ValueError, match="unknown config"):
            get_config("nope")

    def test_replace_and_validation(self):
        cfg = get_config("tiny").replace(num_layers=3)
        assert cfg.num_layers == 3
        assert get_config("tiny").num_layers == 2  # frozen original
        with pytest.raises(ValueError, match="multiple"):
            get_config("tiny").replace(num_heads=3, num_kv_heads=2)

    def test_num_params_matches_init(self):
        model = Model(SMALL)
        params = model.init_params(jax.random.PRNGKey(0))
        actual = sum(int(np.prod(p.shape))
                     for p in jax.tree_util.tree_leaves(params))
        assert actual == SMALL.num_params()

    def test_num_params_tied(self):
        cfg = SMALL.replace(tie_embeddings=True)
        model = Model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        assert "lm_head" not in params
        actual = sum(int(np.prod(p.shape))
                     for p in jax.tree_util.tree_leaves(params))
        assert actual == cfg.num_params()


class TestForward:
    def test_logits_shape_and_dtype(self, small_model):
        model, params = small_model
        toks = _tokens(np.random.default_rng(0), 2, 10)
        logits = model.apply(params, toks)
        assert logits.shape == (2, 10, SMALL.vocab_size)
        assert logits.dtype == jnp.float32

    def test_initial_loss_is_log_vocab(self):
        # Zero-initialized head -> exactly uniform predictions.
        model = Model(SMALL)
        params = model.init_params(jax.random.PRNGKey(0))
        toks = _tokens(np.random.default_rng(1), 2, 17)
        loss = model.loss(params, toks)
        assert np.isclose(float(loss), np.log(SMALL.vocab_size),
                          rtol=1e-6)

    def test_causality(self, small_model):
        """Changing token t+1.. must not change logits at position t."""
        model, params = small_model
        rng = np.random.default_rng(2)
        toks = _tokens(rng, 1, 12)
        base = model.apply(params, toks)
        perturbed = toks.at[0, 7:].set(
            (toks[0, 7:] + 1) % SMALL.vocab_size)
        got = model.apply(params, perturbed)
        np.testing.assert_allclose(got[0, :7], base[0, :7], atol=1e-6)
        assert not np.allclose(got[0, 7:], base[0, 7:], atol=1e-6)

    def test_remat_matches_plain(self, small_model):
        model, params = small_model
        toks = _tokens(np.random.default_rng(3), 2, 9)
        rm = Model(SMALL.replace(remat=True))
        np.testing.assert_allclose(rm.apply(params, toks),
                                   model.apply(params, toks),
                                   atol=1e-6)

    def test_tied_embeddings_forward(self):
        model = Model(SMALL.replace(tie_embeddings=True))
        params = model.init_params(jax.random.PRNGKey(4))
        toks = _tokens(np.random.default_rng(4), 1, 6)
        logits = model.apply(params, toks)
        assert logits.shape == (1, 6, SMALL.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()


class TestKVCache:
    def test_prefill_matches_full_forward(self, small_model):
        model, params = small_model
        toks = _tokens(np.random.default_rng(5), 3, 11)
        full = model.apply(params, toks)
        lengths = jnp.full((3,), 11, jnp.int32)
        _, last = model.prefill(params, toks, lengths, max_len=32)
        np.testing.assert_allclose(last, full[:, -1], atol=1e-5)

    def test_ragged_prefill_ignores_padding(self, small_model):
        """Right-padded junk must not leak into the last-token logits."""
        model, params = small_model
        rng = np.random.default_rng(6)
        real = _tokens(rng, 1, 7)
        padded = jnp.concatenate(
            [real, _tokens(rng, 1, 5)], axis=1)  # junk tail
        _, last_ragged = model.prefill(
            params, padded, jnp.array([7], jnp.int32), max_len=32)
        _, last_exact = model.prefill(
            params, real, jnp.array([7], jnp.int32), max_len=32)
        np.testing.assert_allclose(last_ragged, last_exact, atol=1e-6)

    def test_decode_chain_matches_full_forward(self, small_model):
        model, params = small_model
        toks = _tokens(np.random.default_rng(7), 2, 8)
        lengths = jnp.full((2,), 8, jnp.int32)
        cache, logits = model.prefill(params, toks, lengths, max_len=32)
        seq = toks
        for _ in range(4):
            nxt = model.greedy(logits)
            seq = jnp.concatenate([seq, nxt[:, None]], axis=1)
            cache, logits = model.decode_step(
                params, cache, nxt, jnp.array([True, True]))
            full = model.apply(params, seq)
            np.testing.assert_allclose(logits, full[:, -1], atol=1e-4)
            assert np.array_equal(model.greedy(logits),
                                  model.greedy(full[:, -1]))
