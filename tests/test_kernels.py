"""Pallas split-GEMM kernel vs the jnp reference path (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ozaki_matmul as ozaki_ref

pytest.importorskip("jax.experimental.pallas")

from repro.core.ozaki import slice_matrix  # noqa: E402
from repro.kernels import ops, slicing  # noqa: E402


def _pair(m, k, n, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((m, k)), dtype),
            jnp.asarray(rng.standard_normal((k, n)), dtype))


class TestPallasEquivalence:
    @pytest.mark.parametrize("num_splits", [3, 6])
    def test_matches_df32_reference_bitwise(self, num_splits):
        # Same slicing, same weights, same compensated accumulation:
        # the kernel must agree with the jnp df32 path to the last bit.
        a, b = _pair(128, 128, 128, 0)
        c_pal = ops.ozaki_matmul(a, b, num_splits=num_splits,
                                 interpret=True, out_dtype=jnp.float64)
        c_ref = ozaki_ref(a, b, num_splits=num_splits,
                          accumulator="df32", out_dtype=jnp.float64)
        assert float(jnp.max(jnp.abs(c_pal - c_ref))) == 0.0

    def test_padded_rectangular(self):
        # Shapes that don't divide the tile exercise the zero-padding
        # path (zero slices contribute exactly nothing).
        a, b = _pair(100, 200, 60, 1)
        c_pal = ops.ozaki_matmul(a, b, num_splits=5, interpret=True,
                                 block_m=64, block_n=64, block_k=64,
                                 out_dtype=jnp.float64)
        c_ref = ozaki_ref(a, b, num_splits=5, accumulator="df32",
                          out_dtype=jnp.float64)
        assert float(jnp.max(jnp.abs(c_pal - c_ref))) == 0.0

    def test_accuracy_vs_native(self):
        a, b = _pair(128, 128, 128, 2)
        ref = a.astype(jnp.float64) @ b.astype(jnp.float64)
        denom = (jnp.abs(a).astype(jnp.float64)
                 @ jnp.abs(b).astype(jnp.float64))
        c = ops.ozaki_matmul(a, b, num_splits=6, interpret=True,
                             out_dtype=jnp.float64)
        assert float(jnp.max(jnp.abs(c - ref) / denom)) < 1e-9

    def test_rejects_complex(self):
        a = jnp.ones((32, 32), jnp.complex64)
        with pytest.raises(NotImplementedError):
            ops.ozaki_matmul(a, a, num_splits=3, interpret=True)


class TestV2BitIdentity:
    """v2 == jnp df32 reference to the last bit, everywhere it claims."""

    @pytest.mark.parametrize("m,k,n", [(37, 130, 51), (100, 200, 60),
                                       (64, 96, 64), (1, 129, 1)])
    @pytest.mark.parametrize("num_splits", [3, 5, 9])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
    def test_odd_shapes_all_splits(self, m, k, n, num_splits, dtype):
        a, b = _pair(m, k, n, 7, dtype)
        c_pal = ops.ozaki_matmul(a, b, num_splits=num_splits,
                                 interpret=True, out_dtype=jnp.float64)
        c_ref = ozaki_ref(a, b, num_splits=num_splits,
                          accumulator="df32", out_dtype=jnp.float64)
        assert float(jnp.max(jnp.abs(c_pal - c_ref))) == 0.0

    def test_v1_matches_v2_bitwise(self):
        # Same slices, same schedule, same TwoSum stream: the legacy
        # pair-materializing kernel and the pair-indexing one must
        # agree exactly (the refactor changed data movement only).
        a, b = _pair(100, 200, 60, 8)
        a_sl, _ = slice_matrix(a, 5, axis=1)
        b_sl, _ = slice_matrix(b, 5, axis=0)
        hi2, lo2 = ops.split_gemm_pallas(a_sl, b_sl, 5, interpret=True)
        hi1, lo1 = ops.split_gemm_pallas_v1(a_sl, b_sl, 5,
                                            interpret=True)
        assert float(jnp.max(jnp.abs(hi1 - hi2))) == 0.0
        assert float(jnp.max(jnp.abs(lo1 - lo2))) == 0.0

    def test_tiny_shapes_round_up_to_aligned_tiles(self):
        # Shapes below one MXU tile must pad up to (32, 128), never
        # shrink the block below alignment (the old min() clamp bug).
        a, b = _pair(20, 20, 20, 9)
        c_pal = ops.ozaki_matmul(a, b, num_splits=4, interpret=True,
                                 out_dtype=jnp.float64)
        c_ref = ozaki_ref(a, b, num_splits=4, accumulator="df32",
                          out_dtype=jnp.float64)
        assert float(jnp.max(jnp.abs(c_pal - c_ref))) == 0.0

    def test_model_picked_blocks_match_explicit(self):
        # Letting the tile model choose must not change the numerics.
        a, b = _pair(64, 96, 64, 10)
        auto = ops.ozaki_matmul(a, b, num_splits=4, interpret=True)
        manual = ops.ozaki_matmul(a, b, num_splits=4, interpret=True,
                                  block_m=32, block_n=128, block_k=128)
        assert float(jnp.max(jnp.abs(auto - manual))) == 0.0

    def test_grad_through_offload_bit_identical(self):
        # The pallas_int8 backend inside the offload transform, through
        # jax.grad, must match the jnp fp64_int8 path exactly.
        from repro.core import PrecisionPolicy, offload

        a, b = _pair(64, 96, 48, 11)

        def f(a, b):
            return (a @ b).sum()

        g_pal = jax.grad(offload(f, PrecisionPolicy(
            backend="pallas_int8", default_splits=4, min_dim=16)))(a, b)
        g_ref = jax.grad(offload(f, PrecisionPolicy(
            backend="fp64_int8", default_splits=4, min_dim=16)))(a, b)
        assert bool(jnp.all(g_pal == g_ref))


class TestAccumulatorValidation:
    """Satellite fix: unknown accumulators raise, never silently drop."""

    @pytest.mark.parametrize("fuse", [False, True])
    def test_unsupported_accumulator_raises(self, fuse):
        a, b = _pair(32, 32, 32, 12)
        with pytest.raises(ValueError, match="accumulator"):
            ops.ozaki_matmul(a, b, num_splits=3, accumulator="f64",
                             interpret=True, fuse_slicing=fuse)

    def test_none_means_backend_default(self):
        a, b = _pair(32, 32, 32, 12)
        got = ops.ozaki_matmul(a, b, num_splits=3, accumulator=None,
                               interpret=True)
        want = ops.ozaki_matmul(a, b, num_splits=3, accumulator="df32",
                                interpret=True)
        assert float(jnp.max(jnp.abs(got - want))) == 0.0


class TestFusedSlicing:
    """In-kernel quantization vs the shared slicing spec."""

    @pytest.mark.parametrize("m,k,n", [(37, 130, 51), (64, 96, 64)])
    @pytest.mark.parametrize("num_splits", [3, 6, 9])
    def test_fused_f32_bitwise_vs_reference(self, m, k, n, num_splits):
        # For f32 sources lo == 0, the pair recurrence collapses to the
        # core slicing recurrence, and the fused path must equal the
        # jnp df32 reference exactly.
        a, b = _pair(m, k, n, 13)
        c_fus = ops.ozaki_matmul(a, b, num_splits=num_splits,
                                 interpret=True, fuse_slicing=True,
                                 out_dtype=jnp.float64)
        c_ref = ozaki_ref(a, b, num_splits=num_splits,
                          accumulator="df32", out_dtype=jnp.float64)
        assert float(jnp.max(jnp.abs(c_fus - c_ref))) == 0.0

    @pytest.mark.parametrize("num_splits", [4, 8])
    def test_fused_f64_accuracy_vs_core(self, num_splits):
        # For f64 sources the f32-pair recurrence may pick a different
        # (value-preserving) slice decomposition than the core f64
        # recurrence, so the core comparison is an accuracy bound at
        # the pair's ~48-bit budget, not bit-identity.
        a, b = _pair(37, 130, 51, 14, jnp.float64)
        c_fus = ops.ozaki_matmul(a, b, num_splits=num_splits,
                                 interpret=True, fuse_slicing=True,
                                 out_dtype=jnp.float64)
        c_ref = ozaki_ref(a, b, num_splits=num_splits,
                          accumulator="df32", out_dtype=jnp.float64)
        denom = jnp.abs(a) @ jnp.abs(b)
        assert float(jnp.max(jnp.abs(c_fus - c_ref) / denom)) < 1e-12

    @pytest.mark.parametrize("num_splits", [4, 9])
    def test_fused_f64_bitwise_vs_its_jnp_spec(self, num_splits):
        # The fused kernel's spec for f64 sources is slice_matrix_fused:
        # feeding its slices through the pre-sliced v2 kernel at the
        # same blocks (the compensated accumulation order depends on
        # the k-tiling) must reproduce the fused output exactly.
        from repro.kernels import tile_model

        s = num_splits
        a, b = _pair(37, 130, 51, 15, jnp.float64)
        d = tile_model.select_tiles(37, 130, 51, s, fused=True)
        c_fus = ops.ozaki_matmul(a, b, num_splits=s, interpret=True,
                                 fuse_slicing=True,
                                 out_dtype=jnp.float64)
        a_sl, sig_a = slicing.slice_matrix_fused(a, s, axis=1)
        b_sl, sig_b = slicing.slice_matrix_fused(b, s, axis=0)
        hi, lo = ops.split_gemm_pallas(
            a_sl, b_sl, s, interpret=True, block_m=d.block_m,
            block_n=d.block_n, block_k=d.block_k)
        deferred = 2.0 ** (-slicing.SLICE_BITS * (s + 1))
        c_spec = ((hi.astype(jnp.float64) + lo.astype(jnp.float64))
                  * deferred * sig_a[:, None] * sig_b[None, :])
        assert float(jnp.max(jnp.abs(c_fus - c_spec))) == 0.0
        # And it still lands within the split count's emulation
        # accuracy (~2**(-slice_bits*(s-1)) relative).
        ref = a @ b
        denom = jnp.abs(a) @ jnp.abs(b)
        bound = 1e-5 if s == 4 else 1e-11
        assert float(jnp.max(jnp.abs(c_fus - ref) / denom)) < bound

    def test_fused_backend_spec_resolves_and_computes(self):
        from repro.core import get_backend

        a, b = _pair(64, 96, 48, 16)
        fused = get_backend("pallas_int8_4:fused")
        plain = get_backend("pallas_int8_4")
        got = fused(a, b, out_dtype=jnp.float64)
        want = plain(a, b, out_dtype=jnp.float64)
        assert float(jnp.max(jnp.abs(got - want))) == 0.0

    def test_slice_matrix_fused_f32_equals_core(self):
        x = jnp.asarray(
            np.random.default_rng(17).standard_normal((40, 70)),
            jnp.float32)
        sl_f, sig_f = slicing.slice_matrix_fused(x, 5, axis=1)
        sl_c, sig_c = slice_matrix(x, 5, axis=1)
        assert bool(jnp.all(sl_f == sl_c))
        assert bool(jnp.all(sig_f == sig_c))
