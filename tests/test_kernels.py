"""Pallas split-GEMM kernel vs the jnp reference path (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ozaki_matmul as ozaki_ref

pytest.importorskip("jax.experimental.pallas")

from repro.kernels import ops  # noqa: E402


def _pair(m, k, n, seed):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((m, k)), jnp.float32),
            jnp.asarray(rng.standard_normal((k, n)), jnp.float32))


class TestPallasEquivalence:
    @pytest.mark.parametrize("num_splits", [3, 6])
    def test_matches_df32_reference_bitwise(self, num_splits):
        # Same slicing, same weights, same compensated accumulation:
        # the kernel must agree with the jnp df32 path to the last bit.
        a, b = _pair(128, 128, 128, 0)
        c_pal = ops.ozaki_matmul(a, b, num_splits=num_splits,
                                 interpret=True, out_dtype=jnp.float64)
        c_ref = ozaki_ref(a, b, num_splits=num_splits,
                          accumulator="df32", out_dtype=jnp.float64)
        assert float(jnp.max(jnp.abs(c_pal - c_ref))) == 0.0

    def test_padded_rectangular(self):
        # Shapes that don't divide the tile exercise the zero-padding
        # path (zero slices contribute exactly nothing).
        a, b = _pair(100, 200, 60, 1)
        c_pal = ops.ozaki_matmul(a, b, num_splits=5, interpret=True,
                                 block_m=64, block_n=64, block_k=64,
                                 out_dtype=jnp.float64)
        c_ref = ozaki_ref(a, b, num_splits=5, accumulator="df32",
                          out_dtype=jnp.float64)
        assert float(jnp.max(jnp.abs(c_pal - c_ref))) == 0.0

    def test_accuracy_vs_native(self):
        a, b = _pair(128, 128, 128, 2)
        ref = a.astype(jnp.float64) @ b.astype(jnp.float64)
        denom = (jnp.abs(a).astype(jnp.float64)
                 @ jnp.abs(b).astype(jnp.float64))
        c = ops.ozaki_matmul(a, b, num_splits=6, interpret=True,
                             out_dtype=jnp.float64)
        assert float(jnp.max(jnp.abs(c - ref) / denom)) < 1e-9

    def test_rejects_complex(self):
        a = jnp.ones((32, 32), jnp.complex64)
        with pytest.raises(NotImplementedError):
            ops.ozaki_matmul(a, a, num_splits=3, interpret=True)
