"""Test bootstrap: src/ on the path, float64 enabled, 8 virtual devices.

The device-count flag must land in the environment before the first
``jax`` import: the whole suite runs against 8 virtual CPU devices so
the sharded execution paths (``tests/test_shard.py``) are exercised by
the plain tier-1 ``pytest`` invocation, with no special environment.
An externally provided ``XLA_FLAGS`` that already forces a device
count wins (the multi-device CI job sets its own).
"""

import os
import pathlib
import sys

SRC = pathlib.Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

_FLAGS = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _FLAGS:
    os.environ["XLA_FLAGS"] = (
        _FLAGS + " --xla_force_host_platform_device_count=8").strip()

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)
