"""Test bootstrap: src/ on the path, float64 enabled globally."""

import pathlib
import sys

SRC = pathlib.Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)
