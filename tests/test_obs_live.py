"""Live observability plane tests: the Prometheus /metrics server and
its text rendering, push aggregation, SLO burn-rate tracking, per-site
cost attribution, and the obs diff regression gate."""

import io
import json
import re
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LMConfig
from repro.core import PrecisionPolicy, site_report
from repro.models import Model
from repro.obs import (MetricsRun, MetricsServer, Registry, SLOTracker,
                       attribution, diff_runs, push_snapshot,
                       render_prometheus)
from repro.obs.attrib import publish
from repro.obs.cli import main as obs_main
from repro.obs.diff import parse_derived
from repro.serve import Engine, Request
from repro.serve.scheduler import Scheduler

# -- a small but real Prometheus text-format parser --------------------
# The acceptance criterion is "valid Prometheus text format, parsed by
# a test": every sample line must match the exposition grammar and
# label values must round-trip through the escaping rules.

_SAMPLE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})? (\S+)$')
_LABEL = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def _unescape(v: str) -> str:
    out, i = [], 0
    while i < len(v):
        if v[i] == "\\" and i + 1 < len(v):
            out.append({"n": "\n", '"': '"', "\\": "\\"}
                       .get(v[i + 1], v[i:i + 2]))
            i += 2
        else:
            out.append(v[i])
            i += 1
    return "".join(out)


def parse_prometheus(text: str) -> dict:
    """{(name, ((label, value), ...)): float} plus a _types map."""
    series, types = {}, {}
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(" ", 3)
            types[name] = kind
            continue
        assert not line.startswith("#"), line
        m = _SAMPLE.match(line)
        assert m, f"invalid exposition line: {line!r}"
        name, labels, value = m.groups()
        lbls = {}
        if labels:
            consumed = _LABEL.sub("", labels).replace(",", "")
            assert consumed == "", f"unparsed labels in {line!r}"
            for k, v in _LABEL.findall(labels):
                lbls[k] = _unescape(v)
        key = (name, tuple(sorted(lbls.items())))
        assert key not in series, f"duplicate series {key}"
        series[key] = (float("inf") if value == "+Inf"
                       else float(value))
    series["_types"] = types
    return series


class TestRenderPrometheus:
    def test_counters_gauges_and_types(self):
        reg = Registry()
        reg.counter("site_exec", site="dot0").inc(5)
        reg.gauge("slo_burn_rate").set(1.25)
        parsed = parse_prometheus(render_prometheus(reg.snapshot()))
        assert parsed[("site_exec", (("site", "dot0"),))] == 5
        assert parsed[("slo_burn_rate", ())] == 1.25
        assert parsed["_types"]["site_exec"] == "counter"
        assert parsed["_types"]["slo_burn_rate"] == "gauge"

    def test_label_escaping_round_trips(self):
        # The structural site names the transform produces — with the
        # mesh suffix — plus the pathological escapes of the format.
        names = ['shmap0/dot1 [dp=4,tp=2]', 'a"b', "back\\slash",
                 "new\nline"]
        reg = Registry()
        for n in names:
            reg.counter("site_exec", site=n).inc()
        parsed = parse_prometheus(render_prometheus(reg.snapshot()))
        for n in names:
            assert parsed[("site_exec", (("site", n),))] == 1

    def test_histogram_buckets_sum_count_quantiles(self):
        reg = Registry()
        h = reg.histogram("serve_ttft_s")
        for v in (0.002, 0.03, 0.04, 5.0):
            h.observe(v)
        parsed = parse_prometheus(render_prometheus(reg.snapshot()))
        assert parsed["_types"]["serve_ttft_s"] == "histogram"
        assert parsed[("serve_ttft_s_count", ())] == 4
        assert parsed[("serve_ttft_s_sum", ())] == pytest.approx(5.072)
        # Cumulative buckets: monotone, +Inf bucket == count.
        buckets = sorted(
            ((dict(k[1])["le"], v) for k, v in parsed.items()
             if isinstance(k, tuple) and k[0] == "serve_ttft_s_bucket"),
            key=lambda kv: float(kv[0]))
        counts = [v for _, v in buckets]
        assert counts == sorted(counts)
        assert buckets[-1][0] == "+Inf" and buckets[-1][1] == 4
        for q in ("0.5", "0.95", "0.99"):
            key = ("serve_ttft_s_quantile", (("quantile", q),))
            assert 0.002 <= parsed[key] <= 5.0

    def test_empty_and_name_sanitization(self):
        assert render_prometheus([]) == ""
        reg = Registry()
        reg.counter("bad-name.1").inc()
        text = render_prometheus(reg.snapshot())
        assert "bad_name_1 1" in text


class TestMetricsServer:
    def test_metrics_endpoint_parses(self, tmp_path):
        run = MetricsRun(tmp_path)
        run.registry.counter("site_exec",
                             site="shmap0/dot1 [dp=4,tp=2]").inc(3)
        srv = MetricsServer(run.registry, runs_dir=tmp_path).start()
        try:
            body = urllib.request.urlopen(
                f"{srv.url}/metrics").read().decode()
            parsed = parse_prometheus(body)
            key = ("site_exec",
                   (("site", "shmap0/dot1 [dp=4,tp=2]"),))
            assert parsed[key] == 3

            health = json.loads(urllib.request.urlopen(
                f"{srv.url}/healthz").read())
            assert health["status"] == "ok"
            assert health["series"] == 1

            runs = json.loads(urllib.request.urlopen(
                f"{srv.url}/runs").read())
            assert runs["runs"][0]["run_id"] == run.run_id
            assert runs["runs"][0]["events_torn_lines"] == 0

            with pytest.raises(urllib.error.HTTPError) as e:
                urllib.request.urlopen(f"{srv.url}/nope")
            assert e.value.code == 404
        finally:
            srv.close()
            run.close()

    def test_push_aggregation(self):
        local = Registry()
        local.counter("steps").inc(2)
        srv = MetricsServer(local).start()
        try:
            worker = Registry()
            worker.counter("steps").inc(7)
            ack = push_snapshot(srv.url, "proc1", worker)
            assert ack["ok"] and ack["series"] == 1
            parsed = parse_prometheus(urllib.request.urlopen(
                f"{srv.url}/metrics").read().decode())
            # Local and pushed series coexist, distinguished by src.
            assert parsed[("steps", ())] == 2
            assert parsed[("steps", (("src", "proc1"),))] == 7
            # A second push from the same source replaces, not appends.
            worker.counter("steps").inc(1)
            push_snapshot(srv.url, "proc1", worker)
            parsed = parse_prometheus(urllib.request.urlopen(
                f"{srv.url}/metrics").read().decode())
            assert parsed[("steps", (("src", "proc1"),))] == 8
            assert srv.sources() == ["proc1"]
        finally:
            srv.close()

    def test_bad_push_is_400(self):
        srv = MetricsServer(Registry()).start()
        try:
            req = urllib.request.Request(
                f"{srv.url}/push", data=b'{"metrics": 3}',
                headers={"Content-Type": "application/json"})
            with pytest.raises(urllib.error.HTTPError) as e:
                urllib.request.urlopen(req)
            assert e.value.code == 400
        finally:
            srv.close()


class TestSLOTracker:
    def test_burn_rate_math(self):
        reg = Registry()
        slo = SLOTracker(registry=reg, objective=0.99, window_s=1e6)
        # 100 requests, 1 violation = exactly the 1% error budget.
        for i in range(99):
            assert slo.observe(0.1, 1.0, now=float(i)) == 0.0
        burn = slo.observe(5.0, 1.0, now=99.0)
        assert burn == pytest.approx(1.0)
        assert reg.gauge("slo_burn_rate").value == pytest.approx(1.0)
        assert reg.counter("slo_violations").value == 1
        assert reg.gauge("slo_window_requests").value == 100

    def test_no_target_not_observed(self):
        slo = SLOTracker(objective=0.99)
        assert slo.observe(10.0, None) is None
        assert slo.window_counts() == (0, 0)

    def test_window_pruning(self):
        slo = SLOTracker(objective=0.9, window_s=10.0)
        slo.observe(5.0, 1.0, now=0.0)       # violation
        assert slo.observe(0.1, 1.0, now=1.0) > 0
        # 20s later the violation has aged out of the window.
        assert slo.observe(0.1, 1.0, now=20.0) == 0.0

    def test_warn_page_edges_and_events(self, tmp_path):
        from repro.obs import EventSink, read_events

        reg = Registry()
        sink = EventSink(tmp_path / "ev.jsonl")
        slo = SLOTracker(registry=reg, objective=0.5, window_s=1e6,
                         warn_burn=1.0, page_burn=1.9, sink=sink)
        # Every request violates: burn = 1/(1-0.5) * frac -> 2.0.
        for i in range(3):
            slo.observe(9.0, 1.0, now=float(i))
        sink.close()
        # Edge-triggered: one warn and one page despite 3 violations.
        assert reg.counter("slo_warn").value == 1
        assert reg.counter("slo_page").value == 1
        levels = [e["level"] for e in read_events(tmp_path / "ev.jsonl")
                  if e["type"] == "slo"]
        assert "warn" in levels or "page" in levels

    def test_series_seeded_at_zero(self):
        reg = Registry()
        SLOTracker(registry=reg)
        parsed = parse_prometheus(render_prometheus(reg.snapshot()))
        assert parsed[("slo_burn_rate", ())] == 0.0

    def test_invalid_params(self):
        with pytest.raises(ValueError, match="objective"):
            SLOTracker(objective=1.0)
        with pytest.raises(ValueError, match="window_s"):
            SLOTracker(window_s=0)

    def test_scheduler_edf_reports_late_admission(self):
        class SpySLO:
            late = []

            def late_admission(self, overdue_s):
                self.late.append(overdue_s)

        sched = Scheduler(64, policy="edf", slo=SpySLO())
        req = Request(prompt=[1, 2], max_new_tokens=2,
                      latency_target_s=1e-9)
        sched.submit([req])
        placed = sched.admit([0], lambda slot, r: True)
        assert placed == [(0, req)]
        assert len(SpySLO.late) == 1 and SpySLO.late[0] > 0

    def test_scheduler_fifo_does_not_report(self):
        class SpySLO:
            late = []

            def late_admission(self, overdue_s):
                self.late.append(overdue_s)

        sched = Scheduler(64, policy="fifo", slo=SpySLO())
        req = Request(prompt=[1], max_new_tokens=1,
                      latency_target_s=1e-9)
        sched.submit([req])
        sched.admit([0], lambda slot, r: True)
        assert SpySLO.late == []


def _attrib_events(exec_counts, splits=None, n=256):
    """site_decl + flushed exec counters + one hot-loop span."""
    splits = splits or {}
    events = []
    for site in exec_counts:
        events.append({"type": "site_decl", "site": site,
                       "offloaded": True,
                       "splits": splits.get(site, 6),
                       "m": n, "k": n, "n": n, "batch": 1, "mult": 1,
                       "dtype": "float32"})
    for site, count in exec_counts.items():
        events.append({"type": "metric", "kind": "counter",
                       "name": "site_exec", "labels": {"site": site},
                       "value": count})
    events.append({"type": "span", "name": "train_step", "dur": 3e6})
    return events


class TestAttrib:
    def test_ranking_consistent_with_exec_counts(self):
        # Identical shapes and splits: attribution order must be the
        # execution-count order (the acceptance criterion).
        events = _attrib_events({"dot0": 2, "scan0/dot1": 50,
                                 "shmap0/dot1": 10})
        rows = attribution(events)
        assert [r.site for r in rows] == ["scan0/dot1", "shmap0/dot1",
                                         "dot0"]
        assert [r.execs for r in rows] == [50, 10, 2]
        assert sum(r.wall_share for r in rows) == pytest.approx(1.0)
        assert sum(r.gemm_share for r in rows) == pytest.approx(1.0)
        # Measured wall (3s) is fully distributed.
        assert sum(r.wall_s for r in rows) == pytest.approx(3.0)

    def test_model_costs_scale_with_splits(self):
        events = _attrib_events({"hi": 10, "lo": 10},
                                splits={"hi": 8, "lo": 3})
        rows = attribution(events)
        hi = next(r for r in rows if r.site == "hi")
        lo = next(r for r in rows if r.site == "lo")
        # pairs(8)=36 vs pairs(3)=6 at equal execs.
        assert hi.int8_gemms == pytest.approx(6 * lo.int8_gemms)
        assert hi.wall_share > lo.wall_share

    def test_demotion_suggestion(self):
        events = _attrib_events({"dot0": 4}, splits={"dot0": 6})
        (row,) = attribution(events)
        assert row.demote_to == 4
        # pairs(6)=21 -> pairs(4)=10: saves 11 per problem.
        assert row.demote_save_gemms == pytest.approx(11 * 4)
        assert "s=6 -> s=4" in row.suggestion()
        assert "INT8 GEMMs" in row.suggestion()
        floor = attribution(_attrib_events({"d": 1},
                                           splits={"d": 2}))[0]
        assert floor.demote_to == 1

    def test_publish_gauges(self):
        reg = Registry()
        rows = attribution(_attrib_events({"dot0": 5, "dot1": 1}))
        publish(rows, reg)
        parsed = parse_prometheus(render_prometheus(reg.snapshot()))
        key = ("attrib_wall_share", (("site", "dot0"),))
        assert parsed[key] == pytest.approx(rows[0].wall_share)
        assert ("attrib_int8_gemms",
                (("site", "dot1"),)) in parsed

    def test_cli_attrib_on_recorded_run(self, tmp_path):
        def f(a, b):
            return jnp.sum(jnp.tanh(a @ b) @ b)

        a = jnp.ones((128, 128), jnp.float32)
        pol = PrecisionPolicy(backend="fp64_int8", default_splits=4,
                              min_dim=64)
        sites = site_report(f, pol)(a, a)
        run = MetricsRun(tmp_path)
        run.declare_sites(sites)
        handler = run.site_event_handler()
        for s in sites:
            if s.offloaded:
                handler({"site": s.name})
        with run.tracer.span("train_step"):
            pass
        run.close()
        out = io.StringIO()
        assert obs_main(["attrib", str(tmp_path)], out=out) == 0
        text = out.getvalue()
        assert "cost attribution" in text
        for s in sites:
            if s.offloaded:
                assert s.name in text
        assert "s=4 -> s=2" in text

    def test_cli_attrib_without_decls_fails(self, tmp_path):
        MetricsRun(tmp_path).close()
        out = io.StringIO()
        assert obs_main(["attrib", str(tmp_path)], out=out) == 1
        assert "no offloaded site_decl" in out.getvalue()


def _record_run(tmp_path, name, rows, drift=0):
    """One recorded metrics run with bench rows (+ numerics events)."""
    run = MetricsRun(tmp_path / name)
    for row_name, us, derived in rows:
        run.event("bench_row", name=row_name, us_per_call=us,
                  derived=derived, derived_num=parse_derived(derived))
    run.registry.counter("site_exec", site="dot0").inc(3)
    for i in range(drift):
        run.event("numerics", step=i, site="dot0", splits=4,
                  realized_rel=1e-2, budget=1e-6, drift=True)
    run.close()
    return str(tmp_path / name)


BASE_ROWS = [("lm_step_native", 100.0, "tiny;tokens=256"),
             ("kernel_v2_s6_128", 50.0,
              "hbm_read_reduction=3.50;pairs=21")]


class TestDiff:
    def test_identical_runs_pass(self, tmp_path):
        a = _record_run(tmp_path, "a", BASE_ROWS)
        b = _record_run(tmp_path, "b", BASE_ROWS)
        out = io.StringIO()
        rc = obs_main(["diff", a, b, "--check", "--max-ratio", "1.5"],
                      out=out)
        assert rc == 0
        assert "CHECK OK" in out.getvalue()
        assert "no regressions detected" in out.getvalue()

    def test_timing_regression_flagged(self, tmp_path):
        a = _record_run(tmp_path, "a", BASE_ROWS)
        slow = [("lm_step_native", 400.0, "tiny;tokens=256"),
                BASE_ROWS[1]]
        b = _record_run(tmp_path, "b", slow)
        out = io.StringIO()
        # Without --max-ratio the slowdown is reported, not gated.
        assert obs_main(["diff", a, b, "--check"], out=out) == 0
        assert "slower in B" in out.getvalue()
        out = io.StringIO()
        rc = obs_main(["diff", a, b, "--check", "--max-ratio", "2.0"],
                      out=out)
        assert rc == 1
        assert "slowed 4.00x" in out.getvalue()

    def test_missing_row_and_new_skip(self, tmp_path):
        a = _record_run(tmp_path, "a", BASE_ROWS)
        b = _record_run(tmp_path, "b", [
            ("kernel_v2_s6_128", 0.0,
             "skipped=ImportError;pairs=21")])
        out = io.StringIO()
        assert obs_main(["diff", a, b, "--check"], out=out) == 1
        text = out.getvalue()
        assert "'lm_step_native'" in text and "missing" in text
        assert "skipped" in text

    def test_drift_increase_fails_check(self, tmp_path):
        a = _record_run(tmp_path, "a", BASE_ROWS, drift=0)
        b = _record_run(tmp_path, "b", BASE_ROWS, drift=2)
        out = io.StringIO()
        assert obs_main(["diff", a, b, "--check"], out=out) == 1
        assert "drift count" in out.getvalue()

    def test_vanished_counter_fails_check(self, tmp_path):
        a = _record_run(tmp_path, "a", BASE_ROWS)
        run = MetricsRun(tmp_path / "b")
        for row_name, us, derived in BASE_ROWS:
            run.event("bench_row", name=row_name, us_per_call=us,
                      derived=derived)
        run.close()  # no site_exec counter in this run
        out = io.StringIO()
        rc = obs_main(["diff", a, str(tmp_path / "b"), "--check"],
                      out=out)
        assert rc == 1
        assert "site_exec" in out.getvalue()

    def test_derived_num_round_trip(self):
        assert parse_derived(
            "hbm_read_reduction=3.50;pairs=21;backend=xla_cpu;"
            "modeled=18.76TFLOPS") == {
                "hbm_read_reduction": 3.5, "pairs": 21.0,
                "modeled": 18.76}
        report = diff_runs(
            [{"type": "bench_row", "name": "x", "us_per_call": 1.0,
              "derived": "pairs=21"}],
            [{"type": "bench_row", "name": "x", "us_per_call": 1.0,
              "derived": "pairs=10"}])
        (row,) = report.bench
        assert row.derived["pairs"] == (21.0, 10.0)


SMALL = LMConfig(name="test_obs_live_serve", vocab_size=128,
                 num_layers=1, d_model=64, num_heads=2, num_kv_heads=1,
                 head_dim=32, d_ff=128)


class TestEngineLiveMetrics:
    def test_live_engine_serves_metrics(self, tmp_path):
        """The acceptance criterion: a live serve engine answers
        ``GET /metrics`` in valid Prometheus text format."""
        model = Model(SMALL)
        params = model.init_params(jax.random.PRNGKey(0))
        run = MetricsRun(tmp_path)
        eng = Engine(model, params, batch_slots=2, max_len=64,
                     metrics=run, metrics_port=0,
                     scheduler_policy="edf")
        try:
            url = eng.metrics_server.url
            # The SLO series exists before any request finishes.
            parsed = parse_prometheus(urllib.request.urlopen(
                f"{url}/metrics").read().decode())
            assert parsed[("slo_burn_rate", ())] == 0.0

            rng = np.random.default_rng(0)
            reqs = [Request(prompt=[int(t) for t in
                                    rng.integers(1, 128, 6)],
                            max_new_tokens=4,
                            latency_target_s=60.0)
                    for _ in range(3)]
            eng.run(reqs)
            body = urllib.request.urlopen(
                f"{url}/metrics").read().decode()
            parsed = parse_prometheus(body)
            assert parsed[("serve_ttft_s_count", ())] == 3
            assert parsed[("serve_tokens", ())] == 12
            assert parsed[("slo_window_requests", ())] == 3
            # Generous target: no violations, burn stays 0.
            assert parsed[("slo_burn_rate", ())] == 0.0
            assert parsed["_types"]["serve_ttft_s"] == "histogram"
            runs = json.loads(urllib.request.urlopen(
                f"{url}/runs").read())
            assert runs["runs"][0]["run_id"] == run.run_id
        finally:
            eng.close()
            run.close()
        assert eng.metrics_server is None  # close() is idempotent
        eng.close()

    def test_slo_violation_burns(self, tmp_path):
        model = Model(SMALL)
        params = model.init_params(jax.random.PRNGKey(0))
        run = MetricsRun(tmp_path)
        eng = Engine(model, params, batch_slots=1, max_len=64,
                     metrics=run, slo_objective=0.5)
        eng.run([Request(prompt=[1, 2, 3], max_new_tokens=2,
                         latency_target_s=1e-9)])
        run.close()
        reg = run.registry
        assert reg.counter("slo_violations").value == 1
        assert reg.counter("serve_latency_miss").value == 1
        assert reg.gauge("slo_burn_rate").value == pytest.approx(2.0)

    def test_metrics_port_requires_metrics(self):
        model = Model(SMALL)
        params = model.init_params(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="metrics_port"):
            Engine(model, params, metrics_port=0)
