"""MuST Green's-function contour study: self-consistency + Table-1 trend."""

import numpy as np
import pytest

from repro.apps import must as MU


@pytest.fixture(scope="module")
def small():
    cfg = MU.MustConfig(n=64, block=16, n_energies=5)
    return cfg, MU.build_system(cfg)


class TestDgemmSelfConsistency:
    def test_blocked_inverse_matches_lapack(self, small):
        cfg, system = small
        z = cfg.fermi + 0.2 + 1j * cfg.eta
        m = z * np.eye(cfg.n) - system["H"]
        g_blk = MU._blocked_inverse(m, cfg.block, MU._make_gemm("dgemm"))
        g_dir = np.linalg.inv(m)
        rel = np.max(np.abs(g_blk - g_dir)) / np.max(np.abs(g_dir))
        assert rel < 1e-12

    def test_run_contour_deterministic(self, small):
        cfg, system = small
        r1 = MU.run_contour(cfg, "dgemm", system)
        r2 = MU.run_contour(cfg, "dgemm", system)
        assert r1["etot"] == r2["etot"]
        assert r1["ne"] == r2["ne"]
        np.testing.assert_array_equal(r1["g_diag"], r2["g_diag"])

    def test_reference_against_itself_is_zero(self, small):
        cfg, system = small
        ref = MU.run_contour(cfg, "dgemm", system)
        err = MU.relative_errors(ref, ref)
        assert err["max_real"] == 0.0
        assert err["max_imag"] == 0.0
        assert err["d_etot"] == 0.0

    def test_observables_sane(self, small):
        # -1/pi Im Tr G integrates the spectral weight: with the whole
        # spectrum under the contour window the electron-count analogue
        # must be positive and O(n).
        cfg, system = small
        ref = MU.run_contour(cfg, "dgemm", system)
        assert ref["ne"] > 0
        assert ref["etot"] != 0


class TestEmulatedContour:
    def test_error_decreases_with_splits(self, small):
        cfg, system = small
        ref = MU.run_contour(cfg, "dgemm", system)
        errs = []
        for s in (3, 5, 7):
            test = MU.run_contour(cfg, f"fp64_int8_{s}", system)
            e = MU.relative_errors(ref, test)
            errs.append(e["max_real"])
            assert e["per_z_real"].shape == (cfg.n_energies,)
        assert errs[0] > errs[1] > errs[2]
        assert errs[2] < 1e-8

    def test_observables_converge(self, small):
        cfg, system = small
        ref = MU.run_contour(cfg, "dgemm", system)
        e3 = MU.relative_errors(
            ref, MU.run_contour(cfg, "fp64_int8_3", system))
        e7 = MU.relative_errors(
            ref, MU.run_contour(cfg, "fp64_int8_7", system))
        assert e7["d_etot"] < e3["d_etot"]
        assert e7["d_ne"] < e3["d_ne"]

    def test_unknown_mode_rejected(self, small):
        cfg, system = small
        with pytest.raises(ValueError):
            MU.run_contour(cfg, "fp32", system)

    def test_any_registry_spec_is_a_mode(self, small):
        # The mode string is now a backend spec: adaptive per-site
        # tuning drives the same contour without further plumbing.
        cfg, system = small
        ref = MU.run_contour(cfg, "dgemm", system)
        ada = MU.run_contour(cfg, "adaptive:1e-8", system)
        err = MU.relative_errors(ref, ada)
        assert err["max_real"] < 1e-5  # pole amplification over 1e-8


class TestConfig:
    def test_block_must_divide_n(self):
        with pytest.raises(ValueError):
            MU.MustConfig(n=100, block=48)

    def test_system_spectrum_clusters_at_fermi(self):
        cfg = MU.MustConfig(n=128, block=32)
        system = MU.build_system(cfg)
        evals = system["evals"]
        h = system["H"]
        assert np.max(np.abs(h - h.conj().T)) == 0.0
        near = np.sum(np.abs(evals - cfg.fermi) < 3 * cfg.cluster_width)
        assert near >= cfg.cluster_frac * cfg.n * 0.5
