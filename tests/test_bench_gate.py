"""Unit tests for the CI bench-regression gate (pure logic, no JAX)."""

import json
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.compare_baseline import evaluate, parse_csv, update  # noqa: E402

BASELINE = {
    "tolerance": 0.25,
    "gates": [{"metric": "emul", "reference": "native",
               "max_ratio": 10.0}],
    "required_rows": ["native", "emul"],
}


def test_parse_csv(tmp_path):
    p = tmp_path / "bench.csv"
    p.write_text("name,us_per_call,derived\n"
                 "native,100,x\n"
                 "emul,1000,a=b;sites=18\n"
                 "weird_row_no_number,abc,z\n")
    rows, derived = parse_csv(p)
    assert rows == {"native": 100.0, "emul": 1000.0}
    assert derived["emul"] == {"a": "b", "sites": "18"}


def test_gate_passes_within_tolerance():
    failures, report = evaluate({"native": 100.0, "emul": 1200.0},
                                BASELINE)
    assert not failures and len(report) == 1  # 12.0 <= 10.0 * 1.25


def test_gate_fails_beyond_tolerance():
    failures, _ = evaluate({"native": 100.0, "emul": 1300.0}, BASELINE)
    assert any("REGRESSION" in f for f in failures)  # 13.0 > 12.5


def test_missing_required_row_fails():
    failures, _ = evaluate({"native": 100.0}, BASELINE)
    assert any("emul" in f for f in failures)


def test_zero_reference_fails_loud():
    failures, _ = evaluate({"native": 0.0, "emul": 1.0}, BASELINE)
    assert any("reference is 0" in f for f in failures)


def test_update_rewrites_ratios():
    b = json.loads(json.dumps(BASELINE))
    update({"native": 100.0, "emul": 1500.0}, b)
    assert b["gates"][0]["max_ratio"] == 15.0


def test_update_refuses_incomplete_csv():
    with pytest.raises(SystemExit, match="missing"):
        update({"native": 100.0}, json.loads(json.dumps(BASELINE)))
    with pytest.raises(SystemExit, match="is 0"):
        update({"native": 0.0, "emul": 1.0},
               json.loads(json.dumps(BASELINE)))


def test_derived_check_gates_site_count():
    base = json.loads(json.dumps(BASELINE))
    base["derived_checks"] = [
        {"row": "emul", "key": "offloaded_sites", "min": 18}]
    rows = {"native": 100.0, "emul": 1000.0}
    ok, _ = evaluate(rows, base, {"emul": {"offloaded_sites": "18"}})
    assert not ok
    dropped, _ = evaluate(rows, base,
                          {"emul": {"offloaded_sites": "0"}})
    assert any("fell back to native" in f for f in dropped)
    missing, _ = evaluate(rows, base, {})
    assert any("field missing" in f for f in missing)


def test_skip_row_reference_fails_named():
    # A degraded bench run emits "row,0,skipped=..." — a gate touching
    # it must fail with a named message, not divide by zero.
    derived = {"native": {"skipped": "RuntimeError"}}
    failures, _ = evaluate({"native": 0.0, "emul": 1.0}, BASELINE,
                           derived)
    assert any("skip row" in f and "degraded" in f for f in failures)
    assert not any("ZeroDivision" in f for f in failures)


def test_explicit_skipped_row_name_fails_named():
    base = {"tolerance": 0.25,
            "gates": [{"metric": "emul", "reference": "row_skipped",
                       "max_ratio": 10.0}]}
    failures, _ = evaluate({"row_skipped": 5.0, "emul": 1.0}, base)
    assert any("skip row" in f for f in failures)


def test_malformed_gate_fails_named_not_keyerror():
    base = {"gates": [{"metric": "emul"}]}  # no reference/max_ratio
    failures, _ = evaluate({"emul": 1.0}, base)
    assert any("malformed" in f for f in failures)


def test_malformed_derived_check_fails_named():
    base = {"derived_checks": [{"row": "emul"}]}  # no key/min
    failures, _ = evaluate({"emul": 1.0}, base)
    assert any("malformed" in f for f in failures)


def test_non_numeric_derived_value_fails_named():
    base = {"derived_checks": [
        {"row": "emul", "key": "sites", "min": 1}]}
    failures, _ = evaluate({"emul": 1.0}, base,
                           {"emul": {"sites": "n/a"}})
    assert any("not numeric" in f for f in failures)


def test_update_refuses_skip_row():
    with pytest.raises(SystemExit, match="skip row"):
        update({"native": 100.0, "emul": 50.0},
               json.loads(json.dumps(BASELINE)),
               {"emul": {"skipped": "ImportError"}})


def test_committed_baseline_is_well_formed():
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "benchmarks" / "baseline_quick.json")
    baseline = json.loads(path.read_text())
    assert 0 < baseline["tolerance"] <= 1
    assert baseline["gates"], "baseline must gate something"
    for gate in baseline["gates"]:
        assert gate["max_ratio"] > 0
        assert {"metric", "reference"} <= set(gate)
        # every gated row must also be required, so a silently-missing
        # row cannot skip its gate
        assert gate["metric"] in baseline["required_rows"]
        assert gate["reference"] in baseline["required_rows"]
