"""Precision-plan tuner: calibration, solver, plan artifact, consumers.

The acceptance bar (ISSUE 5): on the LM reduced preset a solved plan
meets the same end-to-end loss tolerance as uniform ``fp64_int8_6``
while issuing strictly fewer INT8 GEMMs per step, and a plan saved
from a dp=8 sharded calibration run is byte-identical to the
single-device plan for the same config.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (PrecisionPolicy, canonical_site, offload,
                        site_report)
from repro.launch.train import build_train_step
from repro.models import Model
from repro.train import AdamW, SyntheticText
from repro.tune import (PLAN_VERSION, Calibrator, PlanError,
                        PlanStaleError, PrecisionPlan, SiteRecord,
                        count_int8_gemms, default_budget,
                        site_set_fingerprint, solve_plan,
                        unpinned_family)

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _two_site_fn(a, b):
    return jnp.sum(jnp.tanh(a @ b) @ b)


def _operands(n=192, seed=0):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((n, n))),
            jnp.asarray(rng.standard_normal((n, n))))


def _record(site="dot0", k=256, dtype="float64", flops=10**7,
            measured=None, probe=6):
    return SiteRecord(site=site, k=k, dtype=dtype, flops=flops,
                      probe_splits=probe, measured_rel=measured,
                      lhs_exp=0, rhs_exp=0)


def _result(records, policy=None, fingerprint="sha256:test"):
    from repro.tune.calibrate import CalibrationResult

    return CalibrationResult(records=records, fingerprint=fingerprint,
                             policy=policy or PrecisionPolicy(),
                             probe_splits=records[0].probe_splits
                             if records else 6)


class TestCanonicalSite:
    def test_strips_spmd_scopes_only(self):
        assert canonical_site("shmap0/dot1") == "dot1"
        assert canonical_site("pmap2/scan0/dot3") == "scan0/dot3"
        assert canonical_site("scan1/cond0/br1/dot0") == \
            "scan1/cond0/br1/dot0"
        assert canonical_site("dot0") == "dot0"

    def test_policy_lookup_is_canonical(self):
        pol = PrecisionPolicy(default_splits=3,
                              site_splits={"scan0/dot1": 9},
                              site_backends={"dot0": "dgemm"})
        assert pol.splits_for("shmap0/scan0/dot1") == 9
        assert pol.splits_for("scan0/dot1") == 9
        assert pol.splits_for("scan0/dot2") == 3
        assert pol.backend_for("shmap1/dot0") == "dgemm"
        assert pol.backend_for("dot1") == pol.backend

    def test_sharded_key_reaches_unsharded_site(self):
        # A key copied from a *sharded* site_report must drive the
        # unsharded program too (and count as matched, not warn).
        pol = PrecisionPolicy(default_splits=3,
                              site_splits={"shmap0/dot1": 8})
        assert pol.splits_for("dot1") == 8
        a, b = _operands(192)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            sites = offload(_two_site_fn,
                            PrecisionPolicy(
                                min_dim=64,
                                site_splits={"shmap0/dot1": 8})
                            ).sites(a, b)
        assert sites[1].splits == 8


class TestCalibrator:
    def test_records_stats_and_returns_native(self):
        a, b = _operands()
        pol = PrecisionPolicy(default_splits=6, min_dim=128)
        cal = Calibrator(_two_site_fn, pol)
        out = cal.run(a, b)
        assert float(out) == pytest.approx(float(_two_site_fn(a, b)),
                                           rel=1e-12)
        res = cal.result()
        assert [r.site for r in res.records] == ["dot0", "dot1"]
        for r in res.records:
            assert r.k == 192
            assert r.dtype == "float64"
            assert r.flops == 2 * 192**3
            # Gaussian operands at probe s=6 measure well below the
            # a-priori model but above the f64 reference floor.
            assert r.measured_rel is not None
            assert 1e-14 < r.measured_rel < 1e-8
            assert r.rhs_exp is not None and r.rhs_exp >= 1
        # dot0's lhs is the raw Gaussian (max |x| ~ 4 -> exp 2-3);
        # dot1's lhs is tanh-squashed (max |x| <= 1 -> exp <= 0).
        assert res.records[0].lhs_exp >= 1
        assert res.records[1].lhs_exp <= 0

    def test_scan_multiplicity_scales_flops(self):
        w = jnp.eye(160)

        def f(x):
            def body(c, _):
                return jnp.tanh(c @ w), None

            y, _ = jax.lax.scan(body, x, None, length=3)
            return y

        x = jnp.ones((160, 160))
        cal = Calibrator(f, PrecisionPolicy(min_dim=64))
        cal.run(x)
        (rec,) = cal.result().records
        assert rec.site == "scan0/dot0"
        assert rec.flops == 3 * 2 * 160**3  # trip multiplicity

    def test_zero_operand_leaves_model_curve(self):
        a, _ = _operands()
        zero = jnp.zeros((192, 192))
        cal = Calibrator(lambda a, b: a @ b, PrecisionPolicy(min_dim=64))
        cal.run(a, zero)
        (rec,) = cal.result().records
        assert rec.measured_rel is None  # degenerate anchor rejected
        assert rec.rhs_exp == 0

    def test_demoted_sites_are_still_measured(self):
        # Re-calibrating under a from_plan policy: a site the old plan
        # demoted to dgemm must still be instrumented, or it would be
        # re-promoted with no measurement to catch the pathology.
        a, b = _operands(192)
        pol = PrecisionPolicy(min_dim=64,
                              site_backends={"dot0": "dgemm"},
                              on_unmatched_site="ignore")
        cal = Calibrator(_two_site_fn, pol)
        out = cal.run(a, b)
        assert float(out) == pytest.approx(float(_two_site_fn(a, b)),
                                           rel=1e-12)
        recs = {r.site: r for r in cal.result().records}
        assert recs["dot0"].measured_rel is not None
        assert recs["dot1"].measured_rel is not None

    def test_signature_drift_raises(self):
        cal = Calibrator(lambda a, b: a @ b, PrecisionPolicy(min_dim=64))
        a, b = _operands(192)
        cal.run(a, b)
        big = jnp.ones((256, 256))
        with pytest.raises(ValueError, match="site set"):
            # Different k -> different eligible site set fingerprint.
            cal.run(big, big)


class TestSolver:
    def test_budget_monotone(self):
        recs = [_record("dot0", k=256), _record("dot1", k=1024)]
        loose = solve_plan(_result(recs), budget=1e-4)
        tight = solve_plan(_result(recs), budget=1e-12)
        for s_loose, s_tight in zip(loose.sites, tight.sites):
            assert s_loose.splits <= s_tight.splits
        assert loose.budget_met and tight.budget_met

    def test_measured_anchor_needs_fewer_splits(self):
        # A site measured 1000x better than the model gets fewer
        # splits than the same site on the model curve.
        modeled = solve_plan(_result([_record(measured=None)]),
                             budget=1e-10)
        anchored = solve_plan(
            _result([_record(measured=1e-13, probe=6)]), budget=1e-10)
        assert anchored.sites[0].splits < modeled.sites[0].splits

    def test_pathological_site_demoted_to_dgemm(self):
        recs = [_record("dot0", measured=1e-3, probe=6),  # >> model
                _record("dot1", measured=1e-11, probe=6)]
        plan = solve_plan(_result(recs), budget=1e-9)
        by = {s.site: s for s in plan.sites}
        assert by["dot0"].backend == "dgemm"
        assert by["dot0"].splits == 0
        assert by["dot1"].backend == "fp64_int8"
        assert plan.demoted_sites() == ["dot0"]

    def test_cost_weighting_prefers_cheap_sites(self):
        # Same error curves, 100x different cost: the expensive site
        # must never end up with more splits than the cheap one.
        recs = [_record("cheap", flops=10**6),
                _record("costly", flops=10**8)]
        plan = solve_plan(_result(recs), budget=1e-9)
        by = {s.site: s.splits for s in plan.sites}
        assert by["costly"] <= by["cheap"]

    def test_unreachable_budget_flagged(self):
        plan = solve_plan(_result([_record()]), budget=1e-300)
        assert not plan.budget_met
        assert all(s.splits == 14 for s in plan.sites)  # MAX_SPLITS

    def test_deterministic(self):
        recs = [_record(f"dot{i}", k=128 * (i + 1)) for i in range(5)]
        a = solve_plan(_result(recs), budget=1e-9)
        b = solve_plan(_result(list(reversed(recs))), budget=1e-9)
        assert a.to_json() == b.to_json()

    def test_default_budget_tracks_loosest_dtype(self):
        f32 = default_budget([_record(dtype="float32")])
        f64 = default_budget([_record(dtype="float64")])
        assert f32 == pytest.approx(32 * np.finfo(np.float32).eps)
        assert f64 == pytest.approx(32 * np.finfo(np.float64).eps)
        assert default_budget([_record(dtype="float32"),
                               _record(dtype="float64")]) == f32
        # ml_dtypes types resolve too (np.finfo would raise here).
        bf16 = default_budget([_record(dtype="bfloat16")])
        assert bf16 == pytest.approx(32 * 2.0 ** -7)
        assert solve_plan(_result([_record(dtype="bfloat16")])
                          ).budget == pytest.approx(bf16)

    def test_unpinned_family(self):
        assert unpinned_family("fp64_int8_6") == "fp64_int8"
        assert unpinned_family("fp64_int8") == "fp64_int8"
        assert unpinned_family("adaptive:1e-9") == "adaptive:1e-9"


class TestPlanArtifact:
    def _plan(self):
        return solve_plan(_result([_record("dot0", k=256),
                                   _record("scan0/dot1", k=512)]),
                          budget=1e-9)

    def test_roundtrip_byte_identical(self, tmp_path):
        plan = self._plan()
        path = plan.save(tmp_path / "p.json")
        loaded = PrecisionPlan.load(path)
        assert loaded.to_json() == plan.to_json()
        assert path.read_text() == plan.to_json()

    def test_unknown_version_rejected(self):
        bad = self._plan().to_json().replace(
            f'"version": {PLAN_VERSION}', '"version": 99')
        with pytest.raises(PlanError, match="version"):
            PrecisionPlan.from_json(bad)

    def test_malformed_rejected(self, tmp_path):
        with pytest.raises(PlanError, match="JSON"):
            PrecisionPlan.from_json("{nope")
        with pytest.raises(PlanError, match="missing"):
            PrecisionPlan.from_json(f'{{"version": {PLAN_VERSION}}}')
        with pytest.raises(PlanError, match="no precision plan"):
            PrecisionPlan.load(tmp_path / "absent.json")

    def test_fingerprint_ignores_free_extents_and_spmd(self):
        a, b = _operands(192)
        pol = PrecisionPolicy(min_dim=64)
        wide = site_report(lambda a, b: a @ b, pol)(
            jnp.ones((640, 192)), b)
        narrow = site_report(lambda a, b: a @ b, pol)(a, b)
        assert site_set_fingerprint(wide) == site_set_fingerprint(narrow)

    def test_validate_sites_stale_names_drift(self):
        plan = self._plan()
        a, b = _operands(192)
        sites = site_report(_two_site_fn,
                            PrecisionPolicy(min_dim=64))(a, b)
        with pytest.raises(PlanStaleError, match="dot1"):
            plan.validate_sites(sites)

    def test_from_plan_policy(self):
        recs = [_record("dot0", k=256, measured=1e-3, probe=6),
                _record("scan0/dot1", k=512)]
        plan = solve_plan(_result(recs), budget=1e-9)
        pol = PrecisionPolicy.from_plan(plan)
        assert pol.backend == "fp64_int8"
        assert pol.backend_for("dot0") == "dgemm"  # demoted
        s = plan.site_splits()["scan0/dot1"]
        assert pol.splits_for("shmap0/scan0/dot1") == s
        assert pol.min_dim == plan.min_dim


class TestPlanTiles:
    """The tile model's canonical block picks in the plan artifact."""

    def _pallas_plan(self):
        recs = [_record("dot0", k=256, dtype="float32"),
                _record("dot1", k=512, dtype="float32",
                        measured=1e-1, probe=6)]  # demoted
        pol = PrecisionPolicy(backend="pallas_int8")
        return solve_plan(_result(recs, policy=pol), budget=1e-6)

    def test_pallas_plan_records_canonical_tiles(self):
        from repro.kernels.tile_model import select_tiles

        plan = self._pallas_plan()
        by_name = {s.site: s for s in plan.sites}
        solved = by_name["dot0"]
        d = select_tiles(None, solved.k, None, solved.splits,
                         dtype=solved.dtype)
        assert solved.tiles == (d.block_m, d.block_n, d.block_k)
        assert "tiles=" in plan.describe()
        # Demoted sites run native: no tile pick.
        assert by_name["dot1"].tiles is None

    def test_jnp_plan_has_no_tiles(self):
        plan = solve_plan(_result([_record("dot0")]), budget=1e-9)
        assert all(s.tiles is None for s in plan.sites)

    def test_tiles_survive_roundtrip_byte_identical(self, tmp_path):
        plan = self._pallas_plan()
        path = plan.save(tmp_path / "p.json")
        loaded = PrecisionPlan.load(path)
        assert loaded.to_json() == plan.to_json()
        assert {s.site: s.tiles for s in loaded.sites} == \
            {s.site: s.tiles for s in plan.sites}

    def test_plan_without_tiles_field_still_loads(self):
        # Plans written before the tile model existed: additive field,
        # same PLAN_VERSION, default None.
        import json as _json

        doc = _json.loads(self._pallas_plan().to_json())
        for s in doc["sites"]:
            s.pop("tiles")
        plan = PrecisionPlan.from_json(_json.dumps(doc))
        assert all(s.tiles is None for s in plan.sites)

    def test_tiles_table_written_next_to_plan(self, tmp_path):
        from repro.tune.plan import tiles_table, write_tiles_table

        plan = self._pallas_plan()
        path = plan.save(tmp_path / "p.json")
        tpath = write_tiles_table(plan, path)
        assert tpath == tmp_path / "p.tiles.json"
        doc = tiles_table(plan)
        assert doc["fingerprint"] == plan.fingerprint
        (row,) = doc["sites"]  # demoted dot1 carries no tiles row
        assert row["site"] == "dot0"
        assert set(row) >= {"tiles", "pairs", "schedule", "vmem_bytes",
                            "mxu_cycles_step", "hbm_bytes_step"}
        import json as _json

        assert _json.loads(tpath.read_text()) == _json.loads(
            _json.dumps(doc, sort_keys=True))

    def test_calibrator_probes_tiles_for_pallas_backend(self):
        a, b = _operands(192)
        pol = PrecisionPolicy(backend="pallas_int8", default_splits=4,
                              min_dim=64)
        cal = Calibrator(_two_site_fn, pol)
        cal.run(a, b)
        result = cal.result()
        assert all(r.tiles is not None for r in result.records)
        assert "tiles=" in result.describe()


class TestUnmatchedSiteOverrides:
    def _run(self, pol):
        a, b = _operands(192)
        return offload(_two_site_fn, pol).sites(a, b)

    def test_typo_warns_by_default(self):
        pol = PrecisionPolicy(min_dim=64,
                              site_splits={"dot7_typo": 9})
        with pytest.warns(UserWarning, match="dot7_typo"):
            self._run(pol)

    def test_strict_mode_raises(self):
        pol = PrecisionPolicy(min_dim=64, site_splits={"nope": 9},
                              on_unmatched_site="raise")
        with pytest.raises(ValueError, match="nope"):
            self._run(pol)

    def test_ignore_mode_is_silent(self):
        pol = PrecisionPolicy(min_dim=64, site_splits={"nope": 9},
                              on_unmatched_site="ignore")
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            self._run(pol)

    def test_matching_keys_do_not_warn(self):
        pol = PrecisionPolicy(min_dim=64, site_splits={"dot1": 7})
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            sites = self._run(pol)
        assert sites[1].splits == 7


class TestOffloadWithPlan:
    def _plan_for(self, fn, *args, min_dim=64):
        pol = PrecisionPolicy(min_dim=min_dim)
        cal = Calibrator(fn, pol)
        cal.run(*args)
        return solve_plan(cal.result())

    def test_plan_drives_per_site_splits(self):
        a, b = _operands(192)
        plan = self._plan_for(_two_site_fn, a, b)
        wrapped = offload(_two_site_fn, plan=plan)
        sites = {s.name: s for s in wrapped.sites(a, b)}
        for ps in plan.sites:
            assert sites[ps.site].splits == ps.splits
        assert float(wrapped(a, b)) == pytest.approx(
            float(_two_site_fn(a, b)), rel=1e-9)

    def test_strict_match_raises_on_drift(self):
        a, b = _operands(192)
        plan = self._plan_for(_two_site_fn, a, b)

        def drifted(a, b):  # one extra eligible site
            return jnp.sum(jnp.tanh(a @ b) @ b @ b)

        with pytest.raises(PlanStaleError, match="Re-run calibration"):
            offload(drifted, plan=plan).sites(a, b)

    def test_subset_match_applies_overlap_without_warning(self):
        a, b = _operands(192)
        plan = self._plan_for(_two_site_fn, a, b)

        def forward_only(a, b):  # covers only the plan's dot0
            return a @ b

        # No explicit policy: subset mode derives an ignore-unmatched
        # policy itself — the plan's extra entries must stay silent.
        wrapped = offload(forward_only, plan=plan,
                          plan_match="subset")
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            (site,) = wrapped.sites(a, b)
        assert site.splits == plan.site_splits()["dot0"]

    def test_per_site_backend_promotion(self):
        # A single site routed to a distinct engine while the rest
        # stay on the default path — observed through a spy backend,
        # so silent fall-through to the default engine cannot pass.
        from repro.core import register_backend
        from repro.core.backends import _FACTORIES, OzakiBackend

        calls = []

        class SpyBackend(OzakiBackend):
            def matmul(self, a, b, **kw):
                calls.append(kw.get("site"))
                return super().matmul(a, b, **kw)

        register_backend("spy_int8", lambda spec, policy, splits, arg:
                         SpyBackend(spec, policy, splits))
        try:
            a, b = _operands(128, seed=3)
            pol = PrecisionPolicy(default_splits=4, min_dim=64,
                                  site_backends={"dot0": "spy_int8_4"})
            wrapped = offload(_two_site_fn, pol)
            sites = wrapped.sites(a, b)
            assert sites[0].backend == "spy_int8_4"
            assert sites[1].backend == "fp64_int8"
            got = float(wrapped(a, b))
            # dot0 (and only dot0) actually executed on the spy.
            assert set(calls) == {"dot0"} and calls
            # s=4 emulation summed over 128^2 outputs: ~1e-2 headroom.
            assert got == pytest.approx(float(_two_site_fn(a, b)),
                                        abs=5e-2)
        finally:
            _FACTORIES.pop("spy_int8", None)


class TestLMTunedPlanAcceptance:
    """Reduced preset: tuned plan == uniform-6 accuracy, fewer GEMMs."""

    def test_tuned_beats_uniform_cost_at_same_tolerance(self):
        cfg = get_config("reduced")
        model = Model(cfg)
        opt = AdamW(lr=3e-3)
        params = model.init_params(jax.random.PRNGKey(0))
        state = opt.init(params)
        data = SyntheticText(cfg.vocab_size, 32, 2, seed=0)
        batch = jnp.asarray(data.batch(0))
        step = build_train_step(model, opt)

        uniform_pol = PrecisionPolicy(backend="fp64_int8",
                                      default_splits=6, min_dim=64)
        cal = Calibrator(step, uniform_pol)
        cal.run(params, state, batch)
        plan = solve_plan(cal.result())
        assert plan.budget_met

        tuned = offload(step, PrecisionPolicy.from_plan(plan),
                        plan=plan)
        uniform = offload(step, uniform_pol)
        n_tuned = count_int8_gemms(tuned.sites(params, state, batch))
        n_uniform = count_int8_gemms(
            uniform.sites(params, state, batch))
        assert n_tuned < n_uniform, (n_tuned, n_uniform)

        _, _, loss_native = jax.jit(step)(params, state, batch)
        _, _, loss_tuned = jax.jit(tuned)(params, state, batch)
        _, _, loss_uniform = jax.jit(uniform)(params, state, batch)
        tol = 1e-4  # the shared end-to-end loss tolerance
        assert abs(float(loss_tuned) - float(loss_native)) <= tol
        assert abs(float(loss_uniform) - float(loss_native)) <= tol


class TestShardedCalibration:
    @needs8
    def test_dp8_plan_byte_identical_to_single_device(self):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.shard import build_mesh, data_parallel_sharding

        cfg = get_config("tiny")
        model = Model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        batch = jnp.asarray(
            SyntheticText(cfg.vocab_size, 64, 8, seed=0).batch(0))
        mesh = build_mesh("dp=8")
        replicated, dp = data_parallel_sharding(mesh)

        def sharded_loss(p, b):
            def per_shard(p_s, b_s):
                return jax.lax.pmean(model.loss(p_s, b_s), "dp")

            return shard_map(per_shard, mesh=mesh,
                             in_specs=(P(), P("dp")),
                             out_specs=P())(p, b)

        pol = PrecisionPolicy(default_splits=6, min_dim=64)
        single = Calibrator(model.loss, pol)
        loss1 = single.run(params, batch)
        sharded = Calibrator(sharded_loss, pol)
        loss8 = sharded.run(jax.device_put(params, replicated),
                            jax.device_put(batch, dp))
        assert float(loss8) == pytest.approx(float(loss1), abs=1e-6)

        plan1 = solve_plan(single.result())
        plan8 = solve_plan(sharded.result())
        # The per-shard stats were pmax-shared across the mesh and all
        # plan fields are mesh-invariant: the artifacts match byte for
        # byte (and so do their fingerprints, by construction).
        assert plan8.to_json() == plan1.to_json()
        # Sharded raw names carry the shmap scope; the records do not.
        assert any(n.startswith("shmap0/")
                   for n in sharded.result().site_names)
        assert {r.site for r in sharded.result().records} == \
            {r.site for r in single.result().records}

    @needs8
    def test_step_plan_is_mesh_specific_under_tp(self):
        """The documented caveat, asserted: a ``--target step`` plan
        calibrated single-device does NOT transfer to a tp mesh.

        Tensor parallelism changes the per-shard contraction extents
        (``d_ff/tp``, per-shard head counts), so the traced site set
        disagrees with the plan fingerprint and plan-strict offload
        raises :class:`PlanStaleError` instead of silently running a
        split schedule tuned for different GEMM shapes.  Re-calibrate
        with the same ``--mesh`` (the tune CLI goes through the
        identical 2-D bring-up) to get a plan for the tp program.
        """
        from repro.launch.train import (build_sharded_train_step,
                                        build_train_step)
        from repro.shard import train_mesh_setup
        from repro.train import AdamW

        cfg = get_config("tiny")
        model = Model(cfg)
        opt = AdamW(lr=3e-3)
        params = model.init_params(jax.random.PRNGKey(0))
        state = opt.init(params)
        batch = jnp.asarray(
            SyntheticText(cfg.vocab_size, 64, 8, seed=0).batch(0))

        pol = PrecisionPolicy(default_splits=6, min_dim=64)
        cal = Calibrator(build_train_step(model, opt), pol)
        cal.run(params, state, batch)
        plan = solve_plan(cal.result())

        mesh, bsh, (p2, o2), _ = train_mesh_setup(
            "dp=4,tp=2", 8, cfg, (params, state))
        sharded = build_sharded_train_step(model, opt, mesh)
        with pytest.raises(PlanStaleError):
            offload(sharded, plan=plan).sites(
                p2, o2, jax.device_put(batch, bsh))
