"""Train-subsystem tests: optimizer, data, checkpointing, offload routing."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LMConfig
from repro.core import PrecisionPolicy, offload
from repro.launch.train import build_train_step, main as train_main
from repro.models import Model
from repro.train import AdamW, CheckpointError, SyntheticText, checkpoint

SMALL = LMConfig(name="test_small", vocab_size=128, num_layers=1,
                 d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
                 d_ff=128)

# Overrides for driving launch.train's CLI at test scale.
_CLI_OVERRIDES = json.dumps({
    "num_layers": 1, "d_model": 64, "num_heads": 2, "num_kv_heads": 1,
    "head_dim": 32, "d_ff": 128, "vocab_size": 128})


def _cli(steps, ckpt_dir, ckpt_every=3):
    return ["--arch", "tiny", "--overrides", _CLI_OVERRIDES,
            "--steps", str(steps), "--seq-len", "16",
            "--global-batch", "2", "--ckpt-dir", str(ckpt_dir),
            "--ckpt-every", str(ckpt_every), "--log-every", "100"]


def _assert_trees_bit_identical(a, b):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype and x.shape == y.shape
        assert x.tobytes() == y.tobytes()


class TestSyntheticText:
    def test_deterministic_per_step(self):
        d = SyntheticText(128, 16, 4, seed=7)
        np.testing.assert_array_equal(d.batch(3), d.batch(3))
        assert not np.array_equal(d.batch(3), d.batch(4))
        d2 = SyntheticText(128, 16, 4, seed=8)
        assert not np.array_equal(d.batch(3), d2.batch(3))

    def test_shape_and_range(self):
        b = SyntheticText(128, 16, 4, seed=0).batch(0)
        assert b.shape == (4, 17) and b.dtype == np.int32
        assert b.min() >= 0 and b.max() < 128

    def test_anchor_skews_marginal(self):
        b = SyntheticText(128, 64, 8, seed=0).batch(0)
        assert (b == 0).mean() > 0.1  # the learnable unigram signal


class TestAdamW:
    def test_update_moves_params_and_counts(self):
        opt = AdamW(lr=1e-2)
        params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
        state = opt.init(params)
        grads = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
        p2, s2 = opt.update(grads, params, state)
        assert int(s2["step"]) == 1
        assert not np.allclose(p2["w"], params["w"])
        assert p2["w"].dtype == params["w"].dtype

    def test_training_reduces_loss(self):
        model = Model(SMALL)
        opt = AdamW(lr=3e-3)
        params = model.init_params(jax.random.PRNGKey(0))
        state = opt.init(params)
        data = SyntheticText(SMALL.vocab_size, 32, 4, seed=0)
        step = jax.jit(build_train_step(model, opt))
        losses = []
        for i in range(8):
            params, state, loss = step(params, state,
                                       jnp.asarray(data.batch(i)))
            losses.append(float(loss))
        assert losses[-1] < losses[0]
        assert losses[0] == pytest.approx(np.log(SMALL.vocab_size),
                                          rel=1e-5)


class TestCheckpoint:
    def test_roundtrip_bit_identical(self, tmp_path):
        tree = {"a": jnp.asarray(np.random.default_rng(0)
                                 .standard_normal((3, 5)), jnp.float32),
                "b": {"c": jnp.arange(4, dtype=jnp.int32)}}
        checkpoint.save(tmp_path, 10, tree)
        got = checkpoint.restore(tmp_path, 10, tree)
        _assert_trees_bit_identical(tree, got)

    def test_latest_step(self, tmp_path):
        assert checkpoint.latest_step(tmp_path / "absent") is None
        tree = {"x": jnp.zeros((2,))}
        checkpoint.save(tmp_path, 3, tree)
        checkpoint.save(tmp_path, 12, tree)
        assert checkpoint.latest_step(tmp_path) == 12

    def test_latest_step_ignores_stranded_tmp(self, tmp_path):
        # A run killed mid-save leaves step_<n>.npz.tmp behind; the
        # resume path must never treat it as a resumable checkpoint.
        tree = {"x": jnp.zeros((2,))}
        checkpoint.save(tmp_path, 4, tree)
        (tmp_path / "step_00000009.npz.tmp").write_bytes(b"partial")
        assert checkpoint.latest_step(tmp_path) == 4
        only_tmp = tmp_path / "only_tmp"
        only_tmp.mkdir()
        (only_tmp / "step_00000002.npz.tmp").write_bytes(b"partial")
        assert checkpoint.latest_step(only_tmp) is None

    def test_save_overwrites_stranded_tmp(self, tmp_path):
        # The next save of the same step must clobber the stranded tmp
        # and land a complete checkpoint.
        tree = {"x": jnp.arange(3, dtype=jnp.float32)}
        (tmp_path / "step_00000004.npz.tmp").write_bytes(b"partial")
        checkpoint.save(tmp_path, 4, tree)
        assert not (tmp_path / "step_00000004.npz.tmp").exists()
        _assert_trees_bit_identical(
            tree, checkpoint.restore(tmp_path, 4, tree))

    def test_missing_step_raises(self, tmp_path):
        with pytest.raises(CheckpointError, match="no checkpoint"):
            checkpoint.restore(tmp_path, 1, {"x": jnp.zeros((2,))})

    def test_structure_mismatch_raises(self, tmp_path):
        checkpoint.save(tmp_path, 1, {"x": jnp.zeros((2,))})
        with pytest.raises(CheckpointError, match="expected"):
            checkpoint.restore(tmp_path, 1, {"x": jnp.zeros((3,))})
        with pytest.raises(CheckpointError, match="leaves"):
            checkpoint.restore(tmp_path, 1,
                               {"x": jnp.zeros((2,)),
                                "y": jnp.zeros((2,))})

    def test_meta_roundtrip_and_restore_ignores_it(self, tmp_path):
        tree = {"x": jnp.arange(3, dtype=jnp.float32)}
        meta = {"plan_fingerprint": "sha256:abc", "backend": None}
        checkpoint.save(tmp_path, 2, tree, meta=meta)
        assert checkpoint.load_meta(tmp_path, 2) == meta
        # The reserved meta key is not a leaf: restore is unaffected.
        _assert_trees_bit_identical(
            tree, checkpoint.restore(tmp_path, 2, tree))

    def test_meta_absent_is_empty(self, tmp_path):
        # Pre-metadata checkpoints (no meta arg) read back as {}.
        checkpoint.save(tmp_path, 1, {"x": jnp.zeros((2,))})
        assert checkpoint.load_meta(tmp_path, 1) == {}
        with pytest.raises(CheckpointError, match="no checkpoint"):
            checkpoint.load_meta(tmp_path, 9)

    def test_kill_and_resume_bit_identical(self, tmp_path):
        """3 steps + resume to 6 == uninterrupted 6, to the bit."""
        dir_a, dir_b = tmp_path / "a", tmp_path / "b"
        train_main(_cli(6, dir_a))
        losses_first = train_main(_cli(3, dir_b))
        losses_resumed = train_main(_cli(6, dir_b))  # resumes at 3
        assert len(losses_first) == 3 and len(losses_resumed) == 3
        assert checkpoint.latest_step(dir_a) == 6
        assert checkpoint.latest_step(dir_b) == 6
        a = np.load(dir_a / "step_00000006.npz")
        b = np.load(dir_b / "step_00000006.npz")
        assert a.files == b.files
        for key in a.files:
            assert a[key].tobytes() == b[key].tobytes(), key

    def test_resume_past_target_is_noop(self, tmp_path):
        d = tmp_path / "c"
        train_main(_cli(2, d, ckpt_every=10))
        assert train_main(_cli(2, d, ckpt_every=10)) == []

    def test_resume_enforces_plan_fingerprint(self, tmp_path):
        """A checkpoint lineage pins its precision plan: resuming with
        a different configuration errors instead of silently training
        at different numerics."""
        d = tmp_path / "planned"
        plan_path = tmp_path / "plan.json"
        tune_args = _cli(2, d) + ["--tune", "1", "--plan",
                                  str(plan_path), "--min-dim", "32"]
        assert train_main(tune_args) == []     # calibrate only
        assert plan_path.exists()
        assert checkpoint.latest_step(d) is None  # tune never trains

        plan_cli = _cli(2, d) + ["--plan", str(plan_path)]
        losses = train_main(plan_cli)
        assert len(losses) == 2
        meta = checkpoint.load_meta(d, 2)
        from repro.tune import PrecisionPlan

        assert meta["plan_fingerprint"] == \
            PrecisionPlan.load(plan_path).fingerprint

        # Resuming without the plan (or, symmetrically, with a plan on
        # a plan-less lineage) must refuse with a clear message.
        with pytest.raises(SystemExit, match="precision plan"):
            train_main(_cli(4, d))
        bare = tmp_path / "bare"
        train_main(_cli(2, bare))
        with pytest.raises(SystemExit, match="precision plan"):
            train_main(_cli(4, bare) + ["--plan", str(plan_path)])

        # The matching plan resumes cleanly.
        assert len(train_main(_cli(4, d) +
                              ["--plan", str(plan_path)])) == 2

        # The explicit upgrade path: adopting a freshly tuned plan on
        # a plan-less lineage with --allow-plan-change proceeds (with
        # a warning) and records the new fingerprint going forward.
        assert len(train_main(_cli(4, bare) +
                              ["--plan", str(plan_path),
                               "--allow-plan-change"])) == 2
        assert checkpoint.load_meta(bare, 4)["plan_fingerprint"] == \
            meta["plan_fingerprint"]

    def test_tune_requires_plan_and_excludes_backend(self, tmp_path):
        with pytest.raises(SystemExit, match="--plan"):
            train_main(_cli(2, tmp_path) + ["--tune", "1"])
        with pytest.raises(SystemExit, match="one"):
            train_main(_cli(2, tmp_path) +
                       ["--plan", "p.json", "--backend", "fp64_int8_4"])


class TestOffloadTraining:
    """The acceptance criterion: a train step's GEMMs route through the
    registry backend, forward and backward, inside the scan bodies."""

    def _setup(self):
        model = Model(SMALL)
        opt = AdamW(lr=3e-3)
        params = model.init_params(jax.random.PRNGKey(0))
        state = opt.init(params)
        batch = jnp.asarray(
            SyntheticText(SMALL.vocab_size, 32, 4, seed=0).batch(0))
        return model, opt, params, state, batch

    def test_sites_cover_forward_and_backward_scans(self):
        model, opt, params, state, batch = self._setup()
        pol = PrecisionPolicy(backend="fp64_int8_4", min_dim=32)
        wrapped = offload(build_train_step(model, opt), pol)
        sites = wrapped.sites(params, state, batch)
        on = [s for s in sites if s.offloaded]
        assert len(on) >= 10
        prefixes = {s.name.split("/")[0] for s in on if "/" in s.name}
        # value_and_grad of a scanned model yields (at least) a forward
        # and a backward scan, and both must carry offloaded sites.
        assert len(prefixes) >= 2, prefixes

    def test_emulated_step_matches_native(self):
        model, opt, params, state, batch = self._setup()
        step = build_train_step(model, opt)
        _, _, loss_native = jax.jit(step)(params, state, batch)
        pol = PrecisionPolicy(backend="fp64_int8_4", min_dim=32)
        wrapped = jax.jit(offload(step, pol))
        p_e, s_e, loss_emul = wrapped(params, state, batch)
        assert float(loss_emul) == pytest.approx(float(loss_native),
                                                 abs=1e-4)
        # and the updated params stay close, i.e. the backward GEMMs
        # were emulated correctly, not skipped
        for le, ln in zip(jax.tree_util.tree_leaves(p_e),
                          jax.tree_util.tree_leaves(
                              jax.jit(step)(params, state, batch)[0])):
            np.testing.assert_allclose(np.asarray(le), np.asarray(ln),
                                       atol=5e-4)
