"""Split selection: error model, empirical probe, per-site adaptivity."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveGemm, estimate_rel_error, measure_splits,
                        ozaki_matmul, predict_splits,
                        splits_for_tolerance)


def _gauss(n, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, n)))


class TestPredict:
    def test_monotone_in_tolerance(self):
        a, b = _gauss(256, 0), _gauss(256, 1)
        splits = [predict_splits(a, b, tol)
                  for tol in (1e-2, 1e-6, 1e-10, 1e-14)]
        assert splits == sorted(splits)
        assert splits[0] < splits[-1]

    def test_uses_both_operands_k_extent(self):
        # The error model depends on the shared contraction extent K;
        # operands whose K extents disagree must be rejected instead of
        # silently modeling a's alone (regression: b used to be dead).
        a = jnp.ones((64, 256))
        with pytest.raises(ValueError, match="disagree"):
            predict_splits(a, jnp.ones((128, 64)), 1e-9)
        s = predict_splits(a, jnp.ones((256, 64)), 1e-9)
        assert s == predict_splits(a, None, 1e-9)  # deprecation shim
        assert s == splits_for_tolerance(1e-9, k=256)

    def test_shape_only_matches_operand_version(self):
        a, b = _gauss(192, 14), _gauss(192, 15)
        for tol in (1e-3, 1e-8, 1e-13):
            assert predict_splits(a, b, tol) == \
                splits_for_tolerance(tol, k=192)

    def test_model_is_conservative(self):
        # The a-priori bound must dominate the observed Gaussian error.
        a, b = _gauss(256, 2), _gauss(256, 3)
        ref = a @ b
        denom = jnp.abs(a) @ jnp.abs(b)
        for s in (3, 5, 7):
            c = ozaki_matmul(a, b, num_splits=s, accumulator="f64",
                             out_dtype=jnp.float64)
            err = float(jnp.max(jnp.abs(c - ref) / denom))
            assert err <= estimate_rel_error(s, 256)


class TestMeasure:
    def test_achieves_tolerance(self):
        a, b = _gauss(192, 4), _gauss(192, 5)
        for tol in (1e-4, 1e-8, 1e-12):
            s, err = measure_splits(a, b, tol)
            assert err <= tol
            # and s is minimal: one fewer split must miss the target
            if s > 1:
                _, err_less = measure_splits(a, b, tol, start=s - 1)
                ref = a @ b
                denom = jnp.abs(a) @ jnp.abs(b)
                c = ozaki_matmul(a, b, num_splits=s - 1,
                                 out_dtype=jnp.float64)
                assert float(jnp.max(jnp.abs(c - ref) / denom)) > tol

    def test_f32_operands_probe_below_f32_floor(self):
        # The probe must upcast its reference: with a float32 reference
        # a 1e-9 target would be unreachable and the search would burn
        # to MAX_SPLITS.
        rng = np.random.default_rng(20)
        a = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
        s, err = measure_splits(a, b, 1e-9)
        assert err <= 1e-9
        assert s <= 8

    def test_measured_at_most_predicted(self):
        # predict errs conservative, so the empirical pick can only be
        # at or below it.
        a, b = _gauss(160, 6), _gauss(160, 7)
        tol = 1e-9
        assert measure_splits(a, b, tol)[0] <= predict_splits(a, b, tol)


class TestAdaptiveGemm:
    def test_site_state_cached_and_honors_tolerance(self):
        gemm = AdaptiveGemm(target_rel=1e-9)
        a, b = _gauss(128, 8), _gauss(128, 9)
        c1 = gemm(a, b, site="tau")
        state = gemm.sites["tau"]
        assert state.err_estimate <= 1e-9
        assert state.calls == 1
        gemm(a, b, site="tau")
        assert gemm.sites["tau"].calls == 2
        assert gemm.sites["tau"].splits == state.splits  # no re-probe
        ref = a @ b
        denom = jnp.abs(a) @ jnp.abs(b)
        assert float(jnp.max(jnp.abs(c1 - ref) / denom)) <= 1e-9

    def test_looser_site_uses_fewer_splits(self):
        a, b = _gauss(128, 10), _gauss(128, 11)
        tight = AdaptiveGemm(target_rel=1e-12)
        loose = AdaptiveGemm(target_rel=1e-3)
        tight(a, b, site="x")
        loose(a, b, site="x")
        assert loose.sites["x"].splits < tight.sites["x"].splits

    def test_report_lists_sites(self):
        gemm = AdaptiveGemm(target_rel=1e-6)
        a, b = _gauss(96, 12), _gauss(96, 13)
        gemm(a, b, site="alpha")
        text = gemm.report()
        assert "alpha" in text and "s=" in text
