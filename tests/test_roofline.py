"""Roofline model over dry-run artifacts."""

import json

import pytest

from repro.analysis.roofline import V5E_PEAKS, CellAnalysis, analyze_cell


def _artifact(**kw):
    base = {"cell": "must_n4096_pod16x16", "num_devices": 256,
            "flops": 1.0e15, "int8_flops": 8.0e14,
            "hbm_bytes": 2.0e12, "collective_bytes": 1.0e10}
    base.update(kw)
    return base


class TestAnalyzeCell:
    def test_from_dict(self):
        r = analyze_cell(_artifact())
        assert isinstance(r, CellAnalysis)
        assert r.cell == "must_n4096_pod16x16"
        expected_compute = (0.2e15 / V5E_PEAKS["flops"]
                            + 0.8e15 / V5E_PEAKS["int8_flops"]) / 256
        assert r.compute_s == pytest.approx(expected_compute)
        assert r.dominant in ("compute", "memory", "collective")
        assert r.bound_s == max(r.compute_s, r.memory_s, r.collective_s)

    def test_from_json_file(self, tmp_path):
        p = tmp_path / "must_n4096_pod16x16.json"
        p.write_text(json.dumps(_artifact()))
        r = analyze_cell(p)
        assert r.num_devices == 256
        assert r.memory_s == pytest.approx(
            2.0e12 / V5E_PEAKS["hbm_gbps"] / 256)

    def test_cell_defaults_to_filename(self, tmp_path):
        p = tmp_path / "decode_32k_pod16x16.json"
        art = _artifact()
        del art["cell"]
        p.write_text(json.dumps(art))
        assert analyze_cell(p).cell == "decode_32k_pod16x16"

    def test_memory_bound_cell(self):
        r = analyze_cell(_artifact(flops=1e12, int8_flops=0,
                                   hbm_bytes=5e14))
        assert r.dominant == "memory"

    def test_peak_overrides(self):
        r = analyze_cell(_artifact(
            int8_flops=0, peaks={"flops": 1.0e12}))
        assert r.compute_s == pytest.approx(1.0e15 / 1.0e12 / 256)

    def test_int8_flops_clamped_to_total(self):
        r = analyze_cell(_artifact(flops=1e12, int8_flops=9e15))
        assert r.compute_s == pytest.approx(
            1e12 / V5E_PEAKS["int8_flops"] / 256)

    def test_bad_artifacts_rejected(self, tmp_path):
        with pytest.raises(ValueError):
            analyze_cell(_artifact(flops="a lot"))
        p = tmp_path / "broken_pod16x16.json"
        p.write_text("[1, 2, 3]")
        with pytest.raises(ValueError):
            analyze_cell(p)
