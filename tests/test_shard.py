"""Sharded execution: meshes, shard_map/pmap offload, dp×tp train.

The acceptance bars for the sharding work, asserted directly below: a
dp=8 data-parallel *emulated* train step on virtual CPU devices must
match the single-device emulated step loss within 1e-10 over 4 steps
with no silent native fallback, and a 2-D dp=4×tp=2 step (tensor
parallelism over attention heads and the SwiGLU hidden dim, bucketed
overlapped gradient all-reduce) must hold the same 1e-10 bar at f64
and under full ``fp64_int8_9`` emulation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import LMConfig
from repro.core import PrecisionPolicy, offload, site_report
from repro.launch.train import (build_sharded_train_step,
                                build_train_step)
from repro.models import Model
from repro.serve.engine import Engine, Request
from repro.shard import (build_mesh, bucket_stats, bucketed_psum,
                         data_parallel_sharding, parse_mesh_spec,
                         reduce_gradients, replicate, ring_all_reduce,
                         shard_batch, train_mesh_setup)
from repro.shard.collectives import bucket_indices
from repro.train import AdamW, SyntheticText

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

# An f64 model: the dp=N equivalence is asserted at 1e-10, which only
# f64 end to end (loss reduction, optimizer moments) can honor.
F64 = LMConfig(name="shard_f64", vocab_size=128, num_layers=1,
               d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
               d_ff=128, dtype="float64", param_dtype="float64")

# A tp-shardable f64 model for the 2-D tests: tp=2 must divide
# num_heads, num_kv_heads and d_ff (F64 above has num_kv_heads=1, so
# it can only run data-parallel).
TP_F64 = LMConfig(name="tp_f64", vocab_size=128, num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                  d_ff=128, dtype="float64", param_dtype="float64")


@pytest.fixture(scope="module")
def mesh8():
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    return build_mesh("dp=8")


class TestMeshHelpers:
    def test_parse_mesh_spec(self):
        assert parse_mesh_spec("dp=8") == {"dp": 8}
        assert parse_mesh_spec("dp=4,tp=2") == {"dp": 4, "tp": 2}

    @pytest.mark.parametrize("bad", ["", "dp", "dp=x", "dp=0",
                                     "dp=2,dp=2"])
    def test_parse_rejects(self, bad):
        with pytest.raises(ValueError, match="mesh spec"):
            parse_mesh_spec(bad)

    def test_build_mesh(self):
        mesh = build_mesh(f"dp={jax.device_count()}")
        assert mesh.size == jax.device_count()
        assert mesh.axis_names == ("dp",)

    def test_build_mesh_too_many_devices_names_recipe(self):
        with pytest.raises(ValueError,
                           match="xla_force_host_platform_device_count"):
            build_mesh(f"dp={jax.device_count() * 2}")

    def test_data_parallel_sharding(self, mesh8):
        rep, dp = data_parallel_sharding(mesh8)
        assert rep.spec == P()
        assert dp.spec == P("dp")
        with pytest.raises(ValueError, match="axis"):
            data_parallel_sharding(mesh8, axis="tp")

    def test_shard_batch_and_replicate(self, mesh8):
        batch = jnp.arange(16 * 3, dtype=jnp.float64).reshape(16, 3)
        sharded = shard_batch(batch, mesh8)
        assert sharded.sharding.is_equivalent_to(
            NamedSharding(mesh8, P("dp")), sharded.ndim)
        np.testing.assert_array_equal(np.asarray(sharded),
                                      np.asarray(batch))
        params = {"w": jnp.ones((4, 4))}
        rep = replicate(params, mesh8)
        assert rep["w"].sharding.is_equivalent_to(
            NamedSharding(mesh8, P()), 2)
        with pytest.raises(ValueError, match="divisible"):
            shard_batch(jnp.ones((9, 2)), mesh8)


class TestTrainMeshSetup:
    """The 2-D CLI bring-up: every spec error fails up front with a
    CLI-grade message, and state lands per the LM axis rules."""

    def test_unknown_axis_lists_valid_names(self):
        with pytest.raises(SystemExit) as ei:
            train_mesh_setup("pp=2", 4)
        msg = str(ei.value)
        assert "'dp'" in msg and "'tp'" in msg
        assert "dp=4,tp=2" in msg  # the example spelling

    def test_device_budget_checked_up_front(self):
        n = jax.device_count()
        with pytest.raises(SystemExit,
                           match="xla_force_host_platform_device_count"):
            train_mesh_setup(f"dp={n},tp=2", 2 * n, TP_F64)

    @needs8
    def test_batch_divides_dp_not_mesh_size(self):
        # dp=4,tp=2 occupies 8 devices but only dp splits the batch:
        # batch 4 is fine (4 % dp == 0) even though 4 % mesh.size != 0.
        mesh, _, _, _ = train_mesh_setup("dp=4,tp=2", 4, TP_F64)
        assert dict(mesh.shape) == {"dp": 4, "tp": 2}
        with pytest.raises(SystemExit, match="dp=4"):
            train_mesh_setup("dp=4,tp=2", 6, TP_F64)

    @needs8
    def test_mesh_is_canonicalized_dp_major(self):
        mesh, _, _, _ = train_mesh_setup("tp=2,dp=4", 4, TP_F64)
        assert mesh.axis_names == ("dp", "tp")

    @needs8
    def test_tp_must_divide_head_counts(self):
        with pytest.raises(SystemExit, match="num_kv_heads"):
            train_mesh_setup("dp=2,tp=4", 4, TP_F64)

    @needs8
    def test_state_placed_per_axis_rules(self):
        model = Model(TP_F64)
        params = model.init_params(jax.random.PRNGKey(0))
        opt_state = AdamW(lr=1e-3).init(params)
        mesh, _, (p, o), (pspecs, _) = train_mesh_setup(
            "dp=2,tp=2", 4, TP_F64, (params, opt_state))
        wq = p["blocks"]["wq"]
        assert wq.sharding.is_equivalent_to(
            NamedSharding(mesh, P(None, None, "tp")), wq.ndim)
        assert p["embed"].sharding.is_equivalent_to(
            NamedSharding(mesh, P()), p["embed"].ndim)
        # AdamW moments mirror the parameter layout leaf for leaf.
        mu_down = o["mu"]["blocks"]["w_down"]
        assert mu_down.sharding.is_equivalent_to(
            NamedSharding(mesh, P(None, "tp", None)), mu_down.ndim)
        assert pspecs["blocks"]["wo"] == P(None, "tp", None)


class TestCollectives:
    def test_bucket_indices_greedy_order_preserving(self):
        leaves = [np.zeros(n, np.float64)
                  for n in (100, 100, 300, 50)]
        # 1600-byte buckets: [0,1] fills one exactly, the oversize
        # leaf 2 gets its own (boundaries never split a leaf), 3 opens
        # the next.
        assert bucket_indices(leaves, 1600) == [[0, 1], [2], [3]]
        n, sizes = bucket_stats(leaves, 1600)
        assert n == 3 and sizes == [1600, 2400, 400]

    @needs8
    def test_bucketed_psum_matches_pmean_bitwise(self, mesh8):
        rng = np.random.default_rng(5)
        tree = {"a": jnp.asarray(rng.standard_normal((8, 16))),
                "b": jnp.asarray(rng.standard_normal((8, 4)))}

        def run(body):
            return shard_map(body, mesh=mesh8, in_specs=(P("dp"),),
                             out_specs=P(), check_rep=False)(tree)

        got = run(lambda t: bucketed_psum(t, "dp",
                                          bucket_bytes=1 << 20,
                                          mean_size=8))
        ref = run(lambda t: jax.tree_util.tree_map(
            lambda x: jax.lax.pmean(x, "dp"), t))
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @needs8
    def test_ring_matches_psum_to_rounding(self, mesh8):
        x = jnp.asarray(
            np.random.default_rng(6).standard_normal((8, 32)))

        def run(body):
            return shard_map(body, mesh=mesh8, in_specs=(P("dp"),),
                             out_specs=P(), check_rep=False)(x)

        ref = run(lambda s: jax.lax.psum(s, "dp") / 8)
        got = run(lambda s: ring_all_reduce(s, "dp", 8, mean=True))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=0, atol=1e-12)

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError, match="bucketed"):
            reduce_gradients({"g": jnp.ones(3)}, "dp", 2, mode="avg")


def _dp_matmul(mesh):
    def per_shard(a_s, b_s):
        y = jnp.tanh(a_s @ b_s) @ b_s
        return y, jax.lax.pmean(jnp.sum(y), "dp")

    return shard_map(per_shard, mesh=mesh,
                     in_specs=(P("dp"), P(None)),
                     out_specs=(P("dp"), P()))


class TestShardMapOffload:
    def test_site_names_shared_and_prefixed(self, mesh8):
        f = _dp_matmul(mesh8)
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((8 * 32, 160)))
        b = jnp.asarray(rng.standard_normal((160, 160)))
        pol = PrecisionPolicy(default_splits=8, min_dim=32)
        report = [s.name for s in site_report(f, pol)(a, b)]
        sites = offload(f, pol).sites(a, b)
        assert report == [s.name for s in sites]
        assert report == ["shmap0/dot0", "shmap0/dot1"]
        # The walker sees per-shard shapes: 256/8 = 32 rows.
        assert sites[0].lhs_shape == (32, 160)
        assert all(s.offloaded for s in sites)

    def test_values_and_grads_match_native(self, mesh8):
        f = _dp_matmul(mesh8)
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.standard_normal((8 * 32, 160)))
        b = jnp.asarray(rng.standard_normal((160, 160)))
        pol = PrecisionPolicy(default_splits=9, min_dim=32,
                              accumulator="f64")
        w = offload(f, pol)
        ref_y, ref_s = f(a, b)
        got_y, got_s = jax.jit(w)(a, b)
        np.testing.assert_allclose(np.asarray(got_y),
                                   np.asarray(ref_y), rtol=0, atol=1e-9)
        assert abs(float(got_s) - float(ref_s)) < 1e-9
        g_ref = jax.grad(lambda a, b: f(a, b)[1])(a, b)
        g_off = jax.grad(lambda a, b: w(a, b)[1])(a, b)
        np.testing.assert_allclose(np.asarray(g_off),
                                   np.asarray(g_ref), rtol=0, atol=1e-8)

    def test_min_dim_gates_per_shard_shape(self, mesh8):
        # 64 global rows = 8 per shard: a min_dim that the *global*
        # shape clears must still gate on the per-shard block, exactly
        # like running one shard on one device would.
        f = _dp_matmul(mesh8)
        a = jnp.ones((64, 160))
        b = jnp.ones((160, 160))
        sites = site_report(f, PrecisionPolicy(min_dim=32))(a, b)
        assert [s.offloaded for s in sites] == [False, False]
        assert "min(m,k,n)=8" in sites[0].reason

    def test_collectives_replay_psum(self, mesh8):
        # A raw psum (not pmean) crossing the offloaded site's output.
        def f(a, b):
            def per_shard(a_s, b_s):
                return jax.lax.psum(a_s @ b_s, "dp")

            return shard_map(per_shard, mesh=mesh8,
                             in_specs=(P("dp"), P(None)),
                             out_specs=P())(a, b)

        rng = np.random.default_rng(2)
        a = jnp.asarray(rng.standard_normal((8 * 32, 160)))
        b = jnp.asarray(rng.standard_normal((160, 160)))
        pol = PrecisionPolicy(default_splits=9, min_dim=32,
                              accumulator="f64")
        np.testing.assert_allclose(np.asarray(offload(f, pol)(a, b)),
                                   np.asarray(f(a, b)), rtol=0,
                                   atol=1e-8)


class TestPallasUnderShardMap:
    """ROADMAP open item: the Pallas kernel (interpret mode off-TPU)
    inside a shard_map body — per-site routing through the fused
    kernel must survive the SPMD rebuild."""

    @needs8
    def test_pallas_backend_inside_shard_map(self, mesh8):
        f = _dp_matmul(mesh8)
        rng = np.random.default_rng(7)
        a = jnp.asarray(rng.standard_normal((8 * 32, 160)))
        b = jnp.asarray(rng.standard_normal((160, 160)))
        pol_pallas = PrecisionPolicy(backend="pallas_int8_6",
                                     default_splits=6, min_dim=32)
        pol_jnp = PrecisionPolicy(backend="fp64_int8_6",
                                  default_splits=6, min_dim=32)
        w_pallas = offload(f, pol_pallas)
        sites = w_pallas.sites(a, b)
        assert [s.name for s in sites] == ["shmap0/dot0",
                                           "shmap0/dot1"]
        assert all(s.offloaded and s.backend == "pallas_int8_6"
                   for s in sites)
        y_pal, s_pal = w_pallas(a, b)
        # Interpret-mode Pallas is bit-identical to the jnp df32 path
        # (the kernel tests pin this for 2-D; here it must hold on the
        # per-shard blocks under shard_map too) ...
        y_jnp, s_jnp = offload(f, pol_jnp)(a, b)
        np.testing.assert_array_equal(np.asarray(y_pal),
                                      np.asarray(y_jnp))
        # ... and close to the native product.
        ref_y, ref_s = f(a, b)
        np.testing.assert_allclose(np.asarray(y_pal),
                                   np.asarray(ref_y), rtol=0,
                                   atol=1e-7)
        assert float(s_pal) == pytest.approx(float(ref_s), abs=1e-5)


class TestPmapOffload:
    def test_pmap_body_offloaded(self):
        ndev = jax.device_count()
        f = jax.pmap(lambda x, y: jnp.tanh(x @ y), axis_name="dp")
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((ndev, 48, 160)))
        y = jnp.asarray(rng.standard_normal((ndev, 160, 160)))
        pol = PrecisionPolicy(default_splits=9, min_dim=32,
                              accumulator="f64")
        w = offload(f, pol)
        sites = w.sites(x, y)
        assert [s.name for s in sites] == ["pmap0/dot0"]
        assert sites[0].offloaded and sites[0].lhs_shape == (48, 160)
        assert [s.name for s in site_report(f, pol)(x, y)] == \
            ["pmap0/dot0"]
        np.testing.assert_allclose(np.asarray(w(x, y)),
                                   np.asarray(f(x, y)), rtol=0,
                                   atol=1e-9)


class TestPjitShardingCompose:
    def test_offload_of_sharded_jit_preserves_partitioning(self, mesh8):
        s_dp = NamedSharding(mesh8, P("dp"))
        s_rep = NamedSharding(mesh8, P())
        f = jax.jit(lambda x, y: jnp.tanh(x @ y),
                    in_shardings=(s_dp, s_rep), out_shardings=s_dp)
        rng = np.random.default_rng(4)
        a = jnp.asarray(rng.standard_normal((8 * 32, 160)))
        b = jnp.asarray(rng.standard_normal((160, 160)))
        pol = PrecisionPolicy(default_splits=9, min_dim=32,
                              accumulator="f64")
        w = offload(f, pol)
        assert [s.name for s in w.sites(a, b)] == ["dot0"]
        out = jax.jit(w)(a, b)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(f(a, b)), rtol=0,
                                   atol=1e-9)
        # The inlined pjit's sharding annotations survived the rewrite.
        assert out.sharding.is_equivalent_to(s_dp, out.ndim)


def _run_steps(step_fn, params, opt_state, data, n_steps,
               batch_sharding=None):
    losses = []
    for i in range(n_steps):
        batch = jnp.asarray(data.batch(i))
        if batch_sharding is not None:
            batch = jax.device_put(batch, batch_sharding)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
    return losses, params


class TestDataParallelTrain:
    """The PR's acceptance bar, asserted directly."""

    # Tolerances: the Ozaki backward GEMM dW = A^T @ g slices A^T with
    # per-row scales, i.e. per-feature maxima over the *local* batch
    # rows — a per-shard quantity — so dp=8 and single-device emulated
    # grads agree only up to the truncation error ~2**(-slice_bits*s).
    # At s=9 that sits below f64 resolution and the 1e-10 bar holds
    # with a fully emulated step; at s=4 the bound is ~6e-8 per GEMM.
    @needs8
    @pytest.mark.parametrize("backend,atol,param_atol", [
        ("", 1e-10, 1e-10),
        ("fp64_int8_9", 1e-10, 1e-9),
        ("fp64_int8_4", 2e-6, 1e-4),
    ])
    def test_dp8_matches_single_device(self, mesh8, backend, atol,
                                       param_atol):
        model = Model(F64)
        opt = AdamW(lr=3e-3)
        data = SyntheticText(F64.vocab_size, 32, 8, seed=0)
        params = model.init_params(jax.random.PRNGKey(0))
        opt_state = opt.init(params)

        single = build_train_step(model, opt)
        sharded = build_sharded_train_step(model, opt, mesh8)
        replicated, batch_sharding = data_parallel_sharding(mesh8)
        params_r, opt_r = jax.device_put((params, opt_state),
                                         replicated)

        if backend:
            pol = PrecisionPolicy(backend=backend, min_dim=32,
                                  accumulator="f64")
            single_w, sharded_w = offload(single, pol), \
                offload(sharded, pol)
            batch0 = jnp.asarray(data.batch(0))
            n_single = sum(s.offloaded for s in
                           single_w.sites(params, opt_state, batch0))
            n_shard = sum(s.offloaded for s in sharded_w.sites(
                params_r, opt_r,
                jax.device_put(batch0, batch_sharding)))
            # No silent native fallback under sharding: every site the
            # single-device step offloads, the dp=8 step offloads too.
            assert n_single == n_shard > 0
            single, sharded = single_w, sharded_w

        loss_1, params_1 = _run_steps(jax.jit(single), params,
                                      opt_state, data, 4)
        loss_8, params_8 = _run_steps(jax.jit(sharded), params_r,
                                      opt_r, data, 4, batch_sharding)
        np.testing.assert_allclose(loss_8, loss_1, rtol=0, atol=atol)
        for a, b in zip(jax.tree_util.tree_leaves(params_1),
                        jax.tree_util.tree_leaves(params_8)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0, atol=param_atol)

    @needs8
    def test_sharded_sites_mirror_single_device_names(self, mesh8):
        model = Model(F64)
        opt = AdamW(lr=3e-3)
        data = SyntheticText(F64.vocab_size, 32, 8, seed=0)
        params = model.init_params(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        batch = jnp.asarray(data.batch(0))
        pol = PrecisionPolicy(backend="fp64_int8_4", min_dim=32)

        single_names = [s.name for s in offload(
            build_train_step(model, opt), pol).sites(params, opt_state,
                                                     batch)]
        shard_names = [s.name for s in offload(
            build_sharded_train_step(model, opt, mesh8), pol).sites(
                params, opt_state, batch)]
        # Same sites, one extra path segment: the shard_map scope.
        assert shard_names == [f"shmap0/{n}" for n in single_names]


class Test2DTrain:
    """dp=4 × tp=2 == single device: this PR's acceptance bar.

    Tensor parallelism changes the *program* (per-shard matmul extents,
    tp psums inside the shard_map body, replicated-param gradients
    completed by the custom_vjp wrappers) but must not change the
    *math*: over 4 steps the losses and the (reassembled) parameters
    match the single-device run to 1e-10 — at f64, and under full
    fp64_int8_9 emulation where the Ozaki truncation error sits below
    f64 resolution.
    """

    def _setup(self):
        model = Model(TP_F64)
        opt = AdamW(lr=3e-3)
        data = SyntheticText(TP_F64.vocab_size, 32, 8, seed=0)
        params = model.init_params(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        return model, opt, data, params, opt_state

    @needs8
    @pytest.mark.parametrize("backend,atol,param_atol", [
        ("", 1e-10, 1e-10),
        ("fp64_int8_9", 1e-10, 1e-9),
    ])
    def test_dp4_tp2_matches_single_device(self, backend, atol,
                                           param_atol):
        model, opt, data, params, opt_state = self._setup()
        single = build_train_step(model, opt)
        mesh, bsh, (p2, o2), _ = train_mesh_setup(
            "dp=4,tp=2", 8, TP_F64, (params, opt_state))
        sharded = build_sharded_train_step(model, opt, mesh)

        if backend:
            pol = PrecisionPolicy(backend=backend, min_dim=32,
                                  accumulator="f64")
            single_w = offload(single, pol)
            sharded_w = offload(sharded, pol)
            batch0 = jnp.asarray(data.batch(0))
            n1 = sum(s.offloaded for s in
                     single_w.sites(params, opt_state, batch0))
            sites2 = sharded_w.sites(p2, o2,
                                     jax.device_put(batch0, bsh))
            assert n1 > 0 and sum(s.offloaded for s in sites2) > 0
            # Every site carries the mesh axes it runs under (the
            # interceptor's spmd_axes), visible in the site report.
            on = [s for s in sites2 if s.offloaded]
            assert all(s.spmd == "dp=4,tp=2" for s in on)
            assert all("[dp=4,tp=2]" in repr(s) for s in on)
            single, sharded = single_w, sharded_w

        loss1, params1 = _run_steps(jax.jit(single), params,
                                    opt_state, data, 4)
        loss2, params2 = _run_steps(jax.jit(sharded), p2, o2, data, 4,
                                    bsh)
        np.testing.assert_allclose(loss2, loss1, rtol=0, atol=atol)
        for a, b in zip(jax.tree_util.tree_leaves(params1),
                        jax.tree_util.tree_leaves(params2)):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=0, atol=param_atol)

    # The blocking reference reduces the same sums in the same order
    # (one fused psum over all leaves vs per-bucket psums of the same
    # leaf blocks), so it holds the strict bar; the ppermute ring
    # accumulates in per-shard order and only promises rounding-level
    # agreement.
    @needs8
    @pytest.mark.parametrize("mode,atol", [("blocking", 1e-10),
                                           ("ppermute", 1e-9)])
    def test_grad_reduce_modes_match(self, mode, atol):
        model, opt, data, params, opt_state = self._setup()
        single = build_train_step(model, opt)
        mesh, bsh, (p2, o2), _ = train_mesh_setup(
            "dp=4,tp=2", 8, TP_F64, (params, opt_state))
        sharded = build_sharded_train_step(model, opt, mesh,
                                           grad_reduce=mode)
        loss1, _ = _run_steps(jax.jit(single), params, opt_state,
                              data, 4)
        loss2, _ = _run_steps(jax.jit(sharded), p2, o2, data, 4, bsh)
        np.testing.assert_allclose(loss2, loss1, rtol=0, atol=atol)

    @needs8
    def test_tp_only_mesh(self):
        # Degenerate dp=1: the whole batch on every tp shard.
        model, opt, data, params, opt_state = self._setup()
        single = build_train_step(model, opt)
        mesh, bsh, (p2, o2), _ = train_mesh_setup(
            "dp=1,tp=2", 8, TP_F64, (params, opt_state))
        sharded = build_sharded_train_step(model, opt, mesh)
        loss1, _ = _run_steps(jax.jit(single), params, opt_state,
                              data, 2)
        loss2, _ = _run_steps(jax.jit(sharded), p2, o2, data, 2, bsh)
        np.testing.assert_allclose(loss2, loss1, rtol=0, atol=1e-10)


class TestShardedServe:
    def _requests(self):
        rng = np.random.default_rng(42)
        return [Request(prompt=[int(t) for t in
                                rng.integers(1, F64.vocab_size,
                                             int(n))],
                        max_new_tokens=8)
                for n in rng.integers(3, 20, 10)]

    @needs8
    def test_sharded_engine_matches_single_device_tokens(self, mesh8):
        model = Model(F64)
        params = model.init_params(jax.random.PRNGKey(0))
        ref = Engine(model, params, batch_slots=8,
                     max_len=64).run(self._requests())
        got = Engine(model, params, batch_slots=8, max_len=64,
                     mesh=mesh8).run(self._requests())
        assert [r.out for r in ref] == [g.out for g in got]

    @needs8
    def test_slots_must_divide_mesh(self, mesh8):
        model = Model(F64)
        params = model.init_params(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="divisible"):
            Engine(model, params, batch_slots=6, mesh=mesh8)

    @needs8
    def test_cache_is_sharded_over_slots(self, mesh8):
        model = Model(F64)
        params = model.init_params(jax.random.PRNGKey(0))
        eng = Engine(model, params, batch_slots=8, max_len=64,
                     mesh=mesh8)
        eng.run(self._requests()[:8])
        assert eng.cache["k"].sharding.is_equivalent_to(
            NamedSharding(mesh8, P(None, "dp")), eng.cache["k"].ndim)

    @needs8
    def test_tp_engine_matches_single_device_tokens(self):
        # 2-D serving goes through GSPMD (params device_put per the LM
        # axis rules, XLA derives the tp collectives) rather than
        # shard_map — the decoded tokens must not change.
        model = Model(TP_F64)
        params = model.init_params(jax.random.PRNGKey(0))
        rng = np.random.default_rng(43)
        reqs = [Request(prompt=[int(t) for t in
                                rng.integers(1, TP_F64.vocab_size,
                                             int(n))],
                        max_new_tokens=8)
                for n in rng.integers(3, 20, 8)]
        ref = Engine(model, params, batch_slots=8,
                     max_len=64).run(reqs)
        mesh = build_mesh("dp=4,tp=2")
        eng = Engine(model, params, batch_slots=8, max_len=64,
                     mesh=mesh)
        got = eng.run(reqs)
        assert [r.out for r in ref] == [g.out for g in got]
        # Params landed tp-sharded, the KV cache splits its kv-head
        # axis over tp and its slot axis over dp.
        wq = eng.params["blocks"]["wq"]
        assert wq.sharding.is_equivalent_to(
            NamedSharding(mesh, P(None, None, "tp")), wq.ndim)
        assert eng.cache["k"].sharding.is_equivalent_to(
            NamedSharding(mesh, P(None, "dp", "tp")),
            eng.cache["k"].ndim)
