"""Sharded execution: mesh helpers, shard_map/pmap offload, dp=N train.

The acceptance bar for the sharding work: a dp=8 data-parallel
*emulated* train step on virtual CPU devices must match the
single-device emulated step loss within 1e-10 over 4 steps, with the
offloaded-site count unchanged (no silent native fallback).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import LMConfig
from repro.core import PrecisionPolicy, offload, site_report
from repro.launch.train import (build_sharded_train_step,
                                build_train_step)
from repro.models import Model
from repro.serve.engine import Engine, Request
from repro.shard import (build_mesh, data_parallel_sharding,
                         parse_mesh_spec, replicate, shard_batch)
from repro.train import AdamW, SyntheticText

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

# An f64 model: the dp=N equivalence is asserted at 1e-10, which only
# f64 end to end (loss reduction, optimizer moments) can honor.
F64 = LMConfig(name="shard_f64", vocab_size=128, num_layers=1,
               d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
               d_ff=128, dtype="float64", param_dtype="float64")


@pytest.fixture(scope="module")
def mesh8():
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    return build_mesh("dp=8")


class TestMeshHelpers:
    def test_parse_mesh_spec(self):
        assert parse_mesh_spec("dp=8") == {"dp": 8}
        assert parse_mesh_spec("dp=4,tp=2") == {"dp": 4, "tp": 2}

    @pytest.mark.parametrize("bad", ["", "dp", "dp=x", "dp=0",
                                     "dp=2,dp=2"])
    def test_parse_rejects(self, bad):
        with pytest.raises(ValueError, match="mesh spec"):
            parse_mesh_spec(bad)

    def test_build_mesh(self):
        mesh = build_mesh(f"dp={jax.device_count()}")
        assert mesh.size == jax.device_count()
        assert mesh.axis_names == ("dp",)

    def test_build_mesh_too_many_devices_names_recipe(self):
        with pytest.raises(ValueError,
                           match="xla_force_host_platform_device_count"):
            build_mesh(f"dp={jax.device_count() * 2}")

    def test_data_parallel_sharding(self, mesh8):
        rep, dp = data_parallel_sharding(mesh8)
        assert rep.spec == P()
        assert dp.spec == P("dp")
        with pytest.raises(ValueError, match="axis"):
            data_parallel_sharding(mesh8, axis="tp")

    def test_shard_batch_and_replicate(self, mesh8):
        batch = jnp.arange(16 * 3, dtype=jnp.float64).reshape(16, 3)
        sharded = shard_batch(batch, mesh8)
        assert sharded.sharding.is_equivalent_to(
            NamedSharding(mesh8, P("dp")), sharded.ndim)
        np.testing.assert_array_equal(np.asarray(sharded),
                                      np.asarray(batch))
        params = {"w": jnp.ones((4, 4))}
        rep = replicate(params, mesh8)
        assert rep["w"].sharding.is_equivalent_to(
            NamedSharding(mesh8, P()), 2)
        with pytest.raises(ValueError, match="divisible"):
            shard_batch(jnp.ones((9, 2)), mesh8)


def _dp_matmul(mesh):
    def per_shard(a_s, b_s):
        y = jnp.tanh(a_s @ b_s) @ b_s
        return y, jax.lax.pmean(jnp.sum(y), "dp")

    return shard_map(per_shard, mesh=mesh,
                     in_specs=(P("dp"), P(None)),
                     out_specs=(P("dp"), P()))


class TestShardMapOffload:
    def test_site_names_shared_and_prefixed(self, mesh8):
        f = _dp_matmul(mesh8)
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((8 * 32, 160)))
        b = jnp.asarray(rng.standard_normal((160, 160)))
        pol = PrecisionPolicy(default_splits=8, min_dim=32)
        report = [s.name for s in site_report(f, pol)(a, b)]
        sites = offload(f, pol).sites(a, b)
        assert report == [s.name for s in sites]
        assert report == ["shmap0/dot0", "shmap0/dot1"]
        # The walker sees per-shard shapes: 256/8 = 32 rows.
        assert sites[0].lhs_shape == (32, 160)
        assert all(s.offloaded for s in sites)

    def test_values_and_grads_match_native(self, mesh8):
        f = _dp_matmul(mesh8)
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.standard_normal((8 * 32, 160)))
        b = jnp.asarray(rng.standard_normal((160, 160)))
        pol = PrecisionPolicy(default_splits=9, min_dim=32,
                              accumulator="f64")
        w = offload(f, pol)
        ref_y, ref_s = f(a, b)
        got_y, got_s = jax.jit(w)(a, b)
        np.testing.assert_allclose(np.asarray(got_y),
                                   np.asarray(ref_y), rtol=0, atol=1e-9)
        assert abs(float(got_s) - float(ref_s)) < 1e-9
        g_ref = jax.grad(lambda a, b: f(a, b)[1])(a, b)
        g_off = jax.grad(lambda a, b: w(a, b)[1])(a, b)
        np.testing.assert_allclose(np.asarray(g_off),
                                   np.asarray(g_ref), rtol=0, atol=1e-8)

    def test_min_dim_gates_per_shard_shape(self, mesh8):
        # 64 global rows = 8 per shard: a min_dim that the *global*
        # shape clears must still gate on the per-shard block, exactly
        # like running one shard on one device would.
        f = _dp_matmul(mesh8)
        a = jnp.ones((64, 160))
        b = jnp.ones((160, 160))
        sites = site_report(f, PrecisionPolicy(min_dim=32))(a, b)
        assert [s.offloaded for s in sites] == [False, False]
        assert "min(m,k,n)=8" in sites[0].reason

    def test_collectives_replay_psum(self, mesh8):
        # A raw psum (not pmean) crossing the offloaded site's output.
        def f(a, b):
            def per_shard(a_s, b_s):
                return jax.lax.psum(a_s @ b_s, "dp")

            return shard_map(per_shard, mesh=mesh8,
                             in_specs=(P("dp"), P(None)),
                             out_specs=P())(a, b)

        rng = np.random.default_rng(2)
        a = jnp.asarray(rng.standard_normal((8 * 32, 160)))
        b = jnp.asarray(rng.standard_normal((160, 160)))
        pol = PrecisionPolicy(default_splits=9, min_dim=32,
                              accumulator="f64")
        np.testing.assert_allclose(np.asarray(offload(f, pol)(a, b)),
                                   np.asarray(f(a, b)), rtol=0,
                                   atol=1e-8)


class TestPallasUnderShardMap:
    """ROADMAP open item: the Pallas kernel (interpret mode off-TPU)
    inside a shard_map body — per-site routing through the fused
    kernel must survive the SPMD rebuild."""

    @needs8
    def test_pallas_backend_inside_shard_map(self, mesh8):
        f = _dp_matmul(mesh8)
        rng = np.random.default_rng(7)
        a = jnp.asarray(rng.standard_normal((8 * 32, 160)))
        b = jnp.asarray(rng.standard_normal((160, 160)))
        pol_pallas = PrecisionPolicy(backend="pallas_int8_6",
                                     default_splits=6, min_dim=32)
        pol_jnp = PrecisionPolicy(backend="fp64_int8_6",
                                  default_splits=6, min_dim=32)
        w_pallas = offload(f, pol_pallas)
        sites = w_pallas.sites(a, b)
        assert [s.name for s in sites] == ["shmap0/dot0",
                                           "shmap0/dot1"]
        assert all(s.offloaded and s.backend == "pallas_int8_6"
                   for s in sites)
        y_pal, s_pal = w_pallas(a, b)
        # Interpret-mode Pallas is bit-identical to the jnp df32 path
        # (the kernel tests pin this for 2-D; here it must hold on the
        # per-shard blocks under shard_map too) ...
        y_jnp, s_jnp = offload(f, pol_jnp)(a, b)
        np.testing.assert_array_equal(np.asarray(y_pal),
                                      np.asarray(y_jnp))
        # ... and close to the native product.
        ref_y, ref_s = f(a, b)
        np.testing.assert_allclose(np.asarray(y_pal),
                                   np.asarray(ref_y), rtol=0,
                                   atol=1e-7)
        assert float(s_pal) == pytest.approx(float(ref_s), abs=1e-5)


class TestPmapOffload:
    def test_pmap_body_offloaded(self):
        ndev = jax.device_count()
        f = jax.pmap(lambda x, y: jnp.tanh(x @ y), axis_name="dp")
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((ndev, 48, 160)))
        y = jnp.asarray(rng.standard_normal((ndev, 160, 160)))
        pol = PrecisionPolicy(default_splits=9, min_dim=32,
                              accumulator="f64")
        w = offload(f, pol)
        sites = w.sites(x, y)
        assert [s.name for s in sites] == ["pmap0/dot0"]
        assert sites[0].offloaded and sites[0].lhs_shape == (48, 160)
        assert [s.name for s in site_report(f, pol)(x, y)] == \
            ["pmap0/dot0"]
        np.testing.assert_allclose(np.asarray(w(x, y)),
                                   np.asarray(f(x, y)), rtol=0,
                                   atol=1e-9)


class TestPjitShardingCompose:
    def test_offload_of_sharded_jit_preserves_partitioning(self, mesh8):
        s_dp = NamedSharding(mesh8, P("dp"))
        s_rep = NamedSharding(mesh8, P())
        f = jax.jit(lambda x, y: jnp.tanh(x @ y),
                    in_shardings=(s_dp, s_rep), out_shardings=s_dp)
        rng = np.random.default_rng(4)
        a = jnp.asarray(rng.standard_normal((8 * 32, 160)))
        b = jnp.asarray(rng.standard_normal((160, 160)))
        pol = PrecisionPolicy(default_splits=9, min_dim=32,
                              accumulator="f64")
        w = offload(f, pol)
        assert [s.name for s in w.sites(a, b)] == ["dot0"]
        out = jax.jit(w)(a, b)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(f(a, b)), rtol=0,
                                   atol=1e-9)
        # The inlined pjit's sharding annotations survived the rewrite.
        assert out.sharding.is_equivalent_to(s_dp, out.ndim)


def _run_steps(step_fn, params, opt_state, data, n_steps,
               batch_sharding=None):
    losses = []
    for i in range(n_steps):
        batch = jnp.asarray(data.batch(i))
        if batch_sharding is not None:
            batch = jax.device_put(batch, batch_sharding)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
    return losses, params


class TestDataParallelTrain:
    """The PR's acceptance bar, asserted directly."""

    # Tolerances: the Ozaki backward GEMM dW = A^T @ g slices A^T with
    # per-row scales, i.e. per-feature maxima over the *local* batch
    # rows — a per-shard quantity — so dp=8 and single-device emulated
    # grads agree only up to the truncation error ~2**(-slice_bits*s).
    # At s=9 that sits below f64 resolution and the 1e-10 bar holds
    # with a fully emulated step; at s=4 the bound is ~6e-8 per GEMM.
    @needs8
    @pytest.mark.parametrize("backend,atol,param_atol", [
        ("", 1e-10, 1e-10),
        ("fp64_int8_9", 1e-10, 1e-9),
        ("fp64_int8_4", 2e-6, 1e-4),
    ])
    def test_dp8_matches_single_device(self, mesh8, backend, atol,
                                       param_atol):
        model = Model(F64)
        opt = AdamW(lr=3e-3)
        data = SyntheticText(F64.vocab_size, 32, 8, seed=0)
        params = model.init_params(jax.random.PRNGKey(0))
        opt_state = opt.init(params)

        single = build_train_step(model, opt)
        sharded = build_sharded_train_step(model, opt, mesh8)
        replicated, batch_sharding = data_parallel_sharding(mesh8)
        params_r, opt_r = jax.device_put((params, opt_state),
                                         replicated)

        if backend:
            pol = PrecisionPolicy(backend=backend, min_dim=32,
                                  accumulator="f64")
            single_w, sharded_w = offload(single, pol), \
                offload(sharded, pol)
            batch0 = jnp.asarray(data.batch(0))
            n_single = sum(s.offloaded for s in
                           single_w.sites(params, opt_state, batch0))
            n_shard = sum(s.offloaded for s in sharded_w.sites(
                params_r, opt_r,
                jax.device_put(batch0, batch_sharding)))
            # No silent native fallback under sharding: every site the
            # single-device step offloads, the dp=8 step offloads too.
            assert n_single == n_shard > 0
            single, sharded = single_w, sharded_w

        loss_1, params_1 = _run_steps(jax.jit(single), params,
                                      opt_state, data, 4)
        loss_8, params_8 = _run_steps(jax.jit(sharded), params_r,
                                      opt_r, data, 4, batch_sharding)
        np.testing.assert_allclose(loss_8, loss_1, rtol=0, atol=atol)
        for a, b in zip(jax.tree_util.tree_leaves(params_1),
                        jax.tree_util.tree_leaves(params_8)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0, atol=param_atol)

    @needs8
    def test_sharded_sites_mirror_single_device_names(self, mesh8):
        model = Model(F64)
        opt = AdamW(lr=3e-3)
        data = SyntheticText(F64.vocab_size, 32, 8, seed=0)
        params = model.init_params(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        batch = jnp.asarray(data.batch(0))
        pol = PrecisionPolicy(backend="fp64_int8_4", min_dim=32)

        single_names = [s.name for s in offload(
            build_train_step(model, opt), pol).sites(params, opt_state,
                                                     batch)]
        shard_names = [s.name for s in offload(
            build_sharded_train_step(model, opt, mesh8), pol).sites(
                params, opt_state, batch)]
        # Same sites, one extra path segment: the shard_map scope.
        assert shard_names == [f"shmap0/{n}" for n in single_names]


class TestShardedServe:
    def _requests(self):
        rng = np.random.default_rng(42)
        return [Request(prompt=[int(t) for t in
                                rng.integers(1, F64.vocab_size,
                                             int(n))],
                        max_new_tokens=8)
                for n in rng.integers(3, 20, 10)]

    @needs8
    def test_sharded_engine_matches_single_device_tokens(self, mesh8):
        model = Model(F64)
        params = model.init_params(jax.random.PRNGKey(0))
        ref = Engine(model, params, batch_slots=8,
                     max_len=64).run(self._requests())
        got = Engine(model, params, batch_slots=8, max_len=64,
                     mesh=mesh8).run(self._requests())
        assert [r.out for r in ref] == [g.out for g in got]

    @needs8
    def test_slots_must_divide_mesh(self, mesh8):
        model = Model(F64)
        params = model.init_params(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="divisible"):
            Engine(model, params, batch_slots=6, mesh=mesh8)

    @needs8
    def test_cache_is_sharded_over_slots(self, mesh8):
        model = Model(F64)
        params = model.init_params(jax.random.PRNGKey(0))
        eng = Engine(model, params, batch_slots=8, max_len=64,
                     mesh=mesh8)
        eng.run(self._requests()[:8])
        assert eng.cache["k"].sharding.is_equivalent_to(
            NamedSharding(mesh8, P(None, "dp")), eng.cache["k"].ndim)
