"""Big-mesh tier: 32 virtual devices, both extreme 2-D shapes.

Runs only in the ``multi-device-large`` CI job
(``XLA_FLAGS=--xla_force_host_platform_device_count=32``); on the
default 8-device tier every test skips.  The point of the tier: the
dp-heavy (16×2) and tp-heavy (4×8) corners of the mesh space exercise
different failure modes — 16-way gradient bucketing vs 8-way tensor
splits of every projection — and both must still equal the
single-device step to 1e-10, at f64 and fully emulated.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LMConfig
from repro.core import PrecisionPolicy, offload
from repro.launch.train import (build_sharded_train_step,
                                build_train_step)
from repro.models import Model
from repro.shard import train_mesh_setup
from repro.train import AdamW, SyntheticText

needs32 = pytest.mark.skipif(
    jax.device_count() < 32,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=32 "
           "(the multi-device-large CI job)")

# tp=8 must divide num_heads, num_kv_heads and d_ff — the shard-test
# config (num_kv_heads=2) caps out at tp=2, so the big-mesh model uses
# 8 full-attention heads.
CFG = LMConfig(name="mesh_large_f64", vocab_size=128, num_layers=2,
               d_model=64, num_heads=8, num_kv_heads=8, head_dim=8,
               d_ff=256, dtype="float64", param_dtype="float64")

STEPS, BATCH, SEQ = 4, 16, 32


@pytest.fixture(scope="module")
def single_device_run():
    model = Model(CFG)
    opt = AdamW(lr=3e-3)
    data = SyntheticText(CFG.vocab_size, SEQ, BATCH, seed=0)
    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    runs = {}
    for backend in ("", "fp64_int8_9"):
        step = build_train_step(model, opt)
        if backend:
            step = offload(step, PrecisionPolicy(
                backend=backend, min_dim=32, accumulator="f64"))
        p, o = params, opt_state
        losses = []
        step = jax.jit(step)
        for i in range(STEPS):
            p, o, loss = step(p, o, jnp.asarray(data.batch(i)))
            losses.append(float(loss))
        runs[backend] = losses
    return model, opt, data, params, opt_state, runs


@needs32
@pytest.mark.parametrize("spec", ["dp=16,tp=2", "dp=4,tp=8"])
@pytest.mark.parametrize("backend", ["", "fp64_int8_9"])
def test_big_mesh_matches_single_device(single_device_run, spec,
                                        backend):
    model, opt, data, params, opt_state, runs = single_device_run
    mesh, bsh, (p, o), _ = train_mesh_setup(spec, BATCH, CFG,
                                            (params, opt_state))
    step = build_sharded_train_step(model, opt, mesh)
    if backend:
        wrapped = offload(step, PrecisionPolicy(
            backend=backend, min_dim=32, accumulator="f64"))
        sites = wrapped.sites(p, o, jax.device_put(
            jnp.asarray(data.batch(0)), bsh))
        assert sum(s.offloaded for s in sites) > 0
        assert all(s.spmd == spec for s in sites)
        step = wrapped
    step = jax.jit(step)
    losses = []
    for i in range(STEPS):
        p, o, loss = step(p, o, jax.device_put(
            jnp.asarray(data.batch(i)), bsh))
        losses.append(float(loss))
    np.testing.assert_allclose(losses, runs[backend], rtol=0,
                               atol=1e-10)
