"""Sharded checkpoints: layout manifest, atomicity, restore/reshard.

The satellite acceptance set for the 2-D parallelism PR: a
kill-and-resume on a dp=2×tp=2 run is bit-identical to the
uninterrupted run, a sharded checkpoint restores onto a *different*
mesh (restore reassembles global arrays, so resharding is the
caller's ``device_put``), and every silently-incompatible layout —
partial shard set, tampered manifest, wrong architecture — raises
:class:`CheckpointError` instead of loading garbage.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LMConfig
from repro.launch import train as launch_train
from repro.models import Model
from repro.shard import build_mesh, train_state_specs
from repro.train import AdamW, checkpoint
from repro.train.checkpoint import CheckpointError

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4")
needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

CFG = LMConfig(name="ckpt_tp_f64", vocab_size=128, num_layers=2,
               d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
               d_ff=128, dtype="float64", param_dtype="float64")


@pytest.fixture(scope="module")
def state():
    model = Model(CFG)
    params = model.init_params(jax.random.PRNGKey(0))
    return params, AdamW(lr=1e-3).init(params)


def _leaves(tree):
    return jax.tree_util.tree_leaves(tree)


class TestShardedLayout:
    @needs4
    def test_roundtrip_and_manifest(self, tmp_path, state):
        mesh = build_mesh("dp=2,tp=2")
        specs = train_state_specs(CFG)
        path = checkpoint.save_sharded(tmp_path, 3, state, specs,
                                       mesh, meta={"k": "v"})
        assert path.name == "step_00000003"
        man = json.loads((path / "manifest.json").read_text())
        assert man["format"] == "repro-sharded-ckpt"
        assert man["mesh"] == {"dp": 2, "tp": 2}
        assert man["shard_axis"] == "tp" and man["num_shards"] == 2
        assert sorted(f.name for f in path.glob("shard_*.npz")) \
            == man["shards"]
        # Per-leaf axis rules pad to leaf rank and use only tp.
        assert all(len(r) == leaf.ndim for r, leaf in
                   zip(man["axis_rules"], _leaves(state)))
        assert {a for r in man["axis_rules"] for a in r if a} == {"tp"}

        assert checkpoint.latest_step(tmp_path) == 3
        assert checkpoint.load_meta(tmp_path, 3) == {"k": "v"}
        like = jax.tree_util.tree_map(jnp.zeros_like, state)
        got = checkpoint.restore(tmp_path, 3, like)
        for a, b in zip(_leaves(got), _leaves(state)):
            np.testing.assert_array_equal(np.asarray(a),
                                          np.asarray(b))

    @needs4
    def test_replicated_leaves_stored_once(self, tmp_path, state):
        mesh = build_mesh("dp=2,tp=2")
        path = checkpoint.save_sharded(tmp_path, 1, state,
                                       train_state_specs(CFG), mesh)
        with np.load(path / "shard_00001_of_00002.npz") as s1:
            n_sharded = len(s1.files)
        with np.load(path / "shard_00000_of_00002.npz") as s0:
            n_all = len(s0.files)
        # Shard 1 holds only the tp-sharded leaves; shard 0 also holds
        # every replicated leaf (embed, norms, step counter, ...).
        assert 0 < n_sharded < n_all == len(_leaves(state))

    @needs4
    def test_partial_shard_set_refused(self, tmp_path, state):
        mesh = build_mesh("dp=2,tp=2")
        path = checkpoint.save_sharded(tmp_path, 2, state,
                                       train_state_specs(CFG), mesh)
        (path / "shard_00001_of_00002.npz").unlink()
        with pytest.raises(CheckpointError, match="partial shard set"):
            checkpoint.restore(tmp_path, 2, state)

    @needs4
    def test_tampered_manifest_refused(self, tmp_path, state):
        mesh = build_mesh("dp=2,tp=2")
        path = checkpoint.save_sharded(tmp_path, 2, state,
                                       train_state_specs(CFG), mesh)
        man = json.loads((path / "manifest.json").read_text())
        man["num_shards"] = 4
        (path / "manifest.json").write_text(json.dumps(man))
        with pytest.raises(CheckpointError, match="fingerprint"):
            checkpoint.restore(tmp_path, 2, state)

    @needs4
    def test_missing_manifest_refused(self, tmp_path, state):
        mesh = build_mesh("dp=2,tp=2")
        path = checkpoint.save_sharded(tmp_path, 2, state,
                                       train_state_specs(CFG), mesh)
        (path / "manifest.json").unlink()
        # Without its manifest the directory is not a checkpoint — for
        # resume discovery ...
        assert checkpoint.latest_step(tmp_path) is None
        # ... and an explicit restore says why.
        with pytest.raises(CheckpointError, match="manifest"):
            checkpoint.restore(tmp_path, 2, state)

    @needs4
    def test_architecture_mismatch_refused(self, tmp_path, state):
        mesh = build_mesh("dp=2,tp=2")
        checkpoint.save_sharded(tmp_path, 2, state,
                                train_state_specs(CFG), mesh)
        with pytest.raises(CheckpointError, match="leaves"):
            checkpoint.restore(tmp_path, 2, {"just": jnp.ones(3)})

    @needs4
    def test_stranded_tmp_dir_invisible_and_cleaned(self, tmp_path,
                                                    state):
        mesh = build_mesh("dp=2,tp=2")
        tmp = tmp_path / "step_00000005.tmp"
        tmp.mkdir()
        (tmp / "shard_00000_of_00002.npz").write_bytes(b"garbage")
        assert checkpoint.latest_step(tmp_path) is None
        path = checkpoint.save_sharded(tmp_path, 5, state,
                                       train_state_specs(CFG), mesh)
        assert not tmp.exists() and path.is_dir()
        assert checkpoint.latest_step(tmp_path) == 5


class TestTrainLoopIntegration:
    """Through the CLI: the loop writes the sharded layout on a tp
    mesh, resumes bit-identically, and reshards across mesh changes."""

    def _run(self, ckpt_dir, steps, mesh="dp=2,tp=2", arch="tiny"):
        return launch_train.main([
            "--arch", arch, "--steps", str(steps), "--seq-len", "32",
            "--global-batch", "4", "--mesh", mesh, "--ckpt-every", "2",
            "--log-every", "10", "--metrics-dir", "none",
            "--ckpt-dir", str(ckpt_dir)])

    @needs4
    def test_kill_and_resume_bit_identical(self, tmp_path):
        a, b = tmp_path / "a", tmp_path / "b"
        self._run(a, 4)          # uninterrupted 0 -> 4
        self._run(b, 2)          # "killed" at 2
        self._run(b, 4)          # resumed 2 -> 4
        da, db = (d / "step_00000004" for d in (a, b))
        assert json.loads((da / "manifest.json").read_text()) \
            == json.loads((db / "manifest.json").read_text())
        for name in ("shard_00000_of_00002.npz",
                     "shard_00001_of_00002.npz"):
            with np.load(da / name) as fa, np.load(db / name) as fb:
                assert fa.files == fb.files
                for key in fa.files:
                    np.testing.assert_array_equal(fa[key], fb[key])

    @needs8
    def test_restore_onto_different_mesh(self, tmp_path):
        d = tmp_path / "ckpt"
        self._run(d, 2, mesh="dp=2,tp=2")
        # Resume the same lineage on a wider mesh: restore reassembles
        # the global arrays, train_mesh_setup reshards them.
        self._run(d, 4, mesh="dp=4,tp=2")
        man = json.loads(
            (d / "step_00000004" / "manifest.json").read_text())
        assert man["mesh"] == {"dp": 4, "tp": 2}

    @needs4
    def test_restore_onto_single_device(self, tmp_path):
        d = tmp_path / "ckpt"
        self._run(d, 2, mesh="dp=2,tp=2")
        losses = launch_train.main([
            "--arch", "tiny", "--steps", "4", "--seq-len", "32",
            "--global-batch", "4", "--ckpt-every", "2",
            "--log-every", "10", "--metrics-dir", "none",
            "--ckpt-dir", str(d)])
        assert len(losses) == 2  # resumed at 2, ran 2 more
        # The single-device continuation writes the plain npz layout.
        assert (d / "step_00000004.npz").is_file()
        assert checkpoint.latest_step(d) == 4
