"""Layered serve stack: paged == dense, chunked == unchunked, packing,
sampling validation, and warm-start transform-cache restarts."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LMConfig
from repro.core import PrecisionPolicy
from repro.models import Model
from repro.obs import MetricsRun
from repro.obs.cli import main as obs_main
from repro.serve import (Engine, PagedKVCache, Request,
                         SamplingParamError, Scheduler)
from repro.shard import build_mesh

SMALL = LMConfig(name="test_paged", vocab_size=128, num_layers=1,
                 d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
                 d_ff=128)

# tp=2-shardable variant for the dp×tp test (kv heads must divide).
TP_CFG = LMConfig(name="test_paged_tp", vocab_size=128, num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                  d_ff=128, dtype="float64", param_dtype="float64")

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def model_params():
    model = Model(SMALL)
    params = model.init_params(jax.random.PRNGKey(0))
    params["lm_head"] = 0.1 * jax.random.normal(
        jax.random.PRNGKey(1), params["lm_head"].shape,
        dtype=jnp.float32)
    return model, params


def _prompts(lengths, seed=0, vocab=128):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(1, vocab, n)]
            for n in lengths]


def _reqs(prompts, max_new=6, **kw):
    return [Request(prompt=p, max_new_tokens=max_new, **kw)
            for p in prompts]


class TestPagedVsDense:
    RAGGED = [3, 17, 9, 31, 12, 24, 5, 16]

    def test_tokens_identical_single_device(self, model_params):
        """The tentpole bar: the paged block-table cache is an
        allocation change, not a numerics change — same greedy tokens
        as the dense rectangle for every ragged prompt."""
        model, params = model_params
        prompts = _prompts(self.RAGGED, seed=11)
        paged = Engine(model, params, batch_slots=4, max_len=64,
                       kv_layout="paged", block_size=16).run(
            _reqs(prompts))
        dense = Engine(model, params, batch_slots=4, max_len=64,
                       kv_layout="dense").run(_reqs(prompts))
        for p, d in zip(paged, dense):
            assert p.out == d.out

    def test_prefill_and_decode_bitwise(self, model_params):
        """Stronger than token identity: the paged programs' logits are
        *bit-identical* to the dense ones (the paged attention gather
        reconstructs the dense buffer layout exactly)."""
        model, params = model_params
        B, T, S, bs = 2, 16, 64, 16
        rng = np.random.default_rng(3)
        tokens = jnp.asarray(rng.integers(1, 128, (B, T)), jnp.int32)
        lengths = jnp.asarray([T, T - 5], jnp.int32)
        dense_cache, dense_logits = jax.jit(
            lambda p, t, n: model.prefill(p, t, n, S))(
            params, tokens, lengths)

        kv = PagedKVCache(model, batch_slots=B, max_len=S,
                          block_size=bs)
        for slot in range(B):
            kv.ensure(slot, int(lengths[slot]))
        cache = kv.sync_table(kv.init_cache())
        piece = lengths
        k, v, logits = jax.jit(model.prefill_chunk_paged)(
            params, cache["k"], cache["v"], cache["block_table"],
            tokens, jnp.zeros((B,), jnp.int32), piece)
        assert (np.asarray(logits) == np.asarray(dense_logits)).all()

        # Eight decode steps stay bitwise too.
        pcache = {"k": k, "v": v,
                  "block_table": cache["block_table"],
                  "length": lengths}
        dcache = dict(dense_cache, length=lengths)
        nxt_p = nxt_d = jnp.asarray(
            np.asarray(model.greedy(logits)), jnp.int32)
        active = jnp.ones((B,), bool)
        for _ in range(8):
            for slot in range(B):
                kv.ensure(slot, int(pcache["length"][slot]) + 1)
            pcache = kv.sync_table(pcache)
            pcache, lp = jax.jit(model.decode_step_paged)(
                params, pcache, nxt_p, active)
            dcache, ld = jax.jit(model.decode_step)(
                params, dcache, nxt_d, active)
            assert (np.asarray(lp) == np.asarray(ld)).all()
            nxt_p = jnp.asarray(np.asarray(model.greedy(lp)), jnp.int32)
            nxt_d = jnp.asarray(np.asarray(model.greedy(ld)), jnp.int32)

    @needs8
    def test_tokens_identical_dp_tp_mesh(self):
        """paged == dense == single-device under a 2-D dp=2×tp=2 mesh."""
        model = Model(TP_CFG)
        params = model.init_params(jax.random.PRNGKey(2))
        prompts = _prompts([3, 14, 7, 22, 11, 18, 5, 9], seed=21,
                           vocab=TP_CFG.vocab_size)
        ref = Engine(model, params, batch_slots=4, max_len=64,
                     kv_layout="dense").run(_reqs(prompts))
        mesh = build_mesh("dp=2,tp=2")
        for layout in ("paged", "dense"):
            got = Engine(model, params, batch_slots=4, max_len=64,
                         mesh=mesh, kv_layout=layout).run(
                _reqs(prompts))
            assert [r.out for r in ref] == [g.out for g in got], layout

    def test_paged_allocates_fewer_blocks(self, model_params):
        """Short prompts in a long-capacity engine must not pay the
        rectangle: the high-water block count stays strictly under the
        dense equivalent."""
        model, params = model_params
        eng = Engine(model, params, batch_slots=4, max_len=64,
                     kv_layout="paged", block_size=16)
        eng.run(_reqs(_prompts([4, 6, 9, 11], seed=5), max_new=4))
        stats = eng.kv.stats()
        assert stats["allocated_hwm"] > 0
        assert stats["allocated_hwm"] < stats["dense_equivalent_blocks"]
        # All blocks returned at drain.
        assert stats["allocated_blocks"] == 0

    def test_block_size_must_divide_max_len(self, model_params):
        model, params = model_params
        with pytest.raises(ValueError, match="multiple of block_size"):
            Engine(model, params, batch_slots=1, max_len=60,
                   kv_layout="paged", block_size=16)


class TestChunkedPrefill:
    def test_chunked_tokens_match_unchunked(self, model_params):
        """Chunk width is invisible in the emitted tokens, both
        layouts (per-position prefill math never reduces over the
        chunk axis, so every chunking is bitwise the same)."""
        model, params = model_params
        prompts = _prompts([5, 19, 33, 12], seed=8)
        for layout in ("paged", "dense"):
            ref = Engine(model, params, batch_slots=2, max_len=64,
                         kv_layout=layout).run(_reqs(prompts))
            chunked = Engine(model, params, batch_slots=2, max_len=64,
                             kv_layout=layout, chunk_tokens=4,
                             chunk_token_budget=8).run(_reqs(prompts))
            for r, c in zip(ref, chunked):
                assert r.out == c.out, layout

    def test_chunked_prefill_bitwise(self, model_params):
        """Model-level: ingesting a prompt in 4-token dense chunks
        reproduces the one-shot prefill logits bit-for-bit."""
        model, params = model_params
        B, T, S = 1, 16, 64
        rng = np.random.default_rng(13)
        tokens = jnp.asarray(rng.integers(1, 128, (B, T)), jnp.int32)
        lengths = jnp.asarray([T], jnp.int32)
        _, ref_logits = jax.jit(
            lambda p, t, n: model.prefill(p, t, n, S))(
            params, tokens, lengths)
        cache = model.init_cache(B, S)
        k, v = cache["k"], cache["v"]
        logits = None
        for pos in range(0, T, 4):
            k, v, logits = jax.jit(model.prefill_chunk)(
                params, k, v, tokens[:, pos:pos + 4],
                jnp.asarray([pos], jnp.int32),
                jnp.asarray([4], jnp.int32))
        assert (np.asarray(logits) == np.asarray(ref_logits)).all()

    def test_packing_beats_pad_to_wave_max(self, model_params):
        """The packing satellite: budget-packed chunk waves compute
        fewer padded tokens (∝ prefill FLOPs) than the old scheme of
        padding every prompt in one wave to the wave max."""
        model, params = model_params
        lengths = [3, 30, 5, 28]
        eng = Engine(model, params, batch_slots=4, max_len=64,
                     chunk_tokens=8, chunk_token_budget=16)
        eng.run(_reqs(_prompts(lengths, seed=9), max_new=2))
        # Old engine: one wave, 4 rows, padded to round_up8(max) = 32.
        old_cost = 4 * 32
        assert eng.runner.real_tokens_total == sum(lengths)
        assert eng.runner.padded_tokens_total < old_cost
        assert eng.runner.waves_total > 1


class TestSampling:
    def test_named_validation_errors(self, model_params):
        model, params = model_params
        eng = Engine(model, params, batch_slots=1, max_len=64)
        cases = [
            dict(prompt=[1, 2], temperature=-0.5),
            dict(prompt=[1, 2], temperature=1.0, seed="abc"),
            dict(prompt=[1, 2], latency_target_s=0.0),
        ]
        for kw in cases:
            with pytest.raises(SamplingParamError):
                eng.run([Request(max_new_tokens=2, **kw)])
        # The named error still is a ValueError (old API contract).
        assert issubclass(SamplingParamError, ValueError)

    def test_temperature_zero_is_greedy(self, model_params):
        model, params = model_params
        prompts = _prompts([7, 9], seed=14)
        greedy = Engine(model, params, batch_slots=2, max_len=64).run(
            _reqs(prompts))
        explicit = Engine(model, params, batch_slots=2,
                          max_len=64).run(
            _reqs(prompts, temperature=0.0, seed=123))
        for g, e in zip(greedy, explicit):
            assert g.out == e.out

    def test_sampled_request_deterministic_across_batching(
            self, model_params):
        """temperature>0 draws come from a per-request stream seeded by
        (seed, emission index): batch neighbours cannot change them."""
        model, params = model_params
        prompt = _prompts([9], seed=15)[0]
        solo, = Engine(model, params, batch_slots=1, max_len=64).run(
            [Request(prompt=prompt, max_new_tokens=6, temperature=0.8,
                     seed=42)])
        noise = _prompts([5, 11, 7], seed=16)
        batched = Engine(model, params, batch_slots=4, max_len=64).run(
            [Request(prompt=prompt, max_new_tokens=6, temperature=0.8,
                     seed=42)] + _reqs(noise))
        assert batched[0].out == solo.out
        # Different seed, different draw (overwhelmingly likely).
        other, = Engine(model, params, batch_slots=1, max_len=64).run(
            [Request(prompt=prompt, max_new_tokens=6, temperature=0.8,
                     seed=43)])
        assert other.out != solo.out


class TestScheduler:
    def test_edf_orders_by_deadline(self):
        sched = Scheduler(max_len=64, policy="edf")
        slow = Request(prompt=[1], max_new_tokens=1)
        fast = Request(prompt=[2], max_new_tokens=1,
                       latency_target_s=0.01)
        mid = Request(prompt=[3], max_new_tokens=1,
                      latency_target_s=5.0)
        sched.submit([slow, mid, fast], now=100.0)
        placed = sched.admit([0, 1, 2], lambda s, r: True)
        assert [r for _, r in placed] == [fast, mid, slow]
        # Lowest free slot goes to the earliest deadline.
        assert placed[0][0] == 0

    def test_fifo_preserves_submission_order(self):
        sched = Scheduler(max_len=64, policy="fifo")
        reqs = [Request(prompt=[i], max_new_tokens=1,
                        latency_target_s=9.0 - i) for i in range(3)]
        sched.submit(reqs, now=1.0)
        placed = sched.admit([0, 1, 2], lambda s, r: True)
        assert [r for _, r in placed] == reqs

    def test_head_of_line_blocks(self):
        sched = Scheduler(max_len=64, policy="fifo")
        big = Request(prompt=[1], max_new_tokens=1)
        small = Request(prompt=[2], max_new_tokens=1)
        sched.submit([big, small], now=1.0)
        placed = sched.admit([0], lambda s, r: r is not big)
        assert placed == []  # small must not overtake big
        assert sched.pending == 2


class TestWarmStart:
    def _run_once(self, model, params, warm_dir, metrics_dir, prompts):
        """One serve 'process': fresh engine, fresh transform caches
        (the offload LRU lives on the wrapper, so a new Engine is a
        faithful stand-in for a restarted process)."""
        pol = PrecisionPolicy(default_splits=6, min_dim=32)
        with MetricsRun(metrics_dir) as run:
            eng = Engine(model, params, batch_slots=2, max_len=64,
                         policy=pol, warm_cache_dir=warm_dir,
                         metrics=run)
            out = [r.out for r in eng.run(_reqs(prompts, max_new=4))]
            info = eng.runner._prefill_wrapped.persist_info()
            dinfo = eng.runner._decode_wrapped.persist_info()
        return out, info, dinfo

    def test_restart_reuses_persisted_transforms(self, model_params,
                                                 tmp_path):
        """Kill-and-restart: the second process must take byte-identical
        transform decisions from disk and re-trace nothing."""
        model, params = model_params
        warm = tmp_path / "warm"
        prompts = _prompts([5, 9, 13], seed=17)
        out1, info1, dinfo1 = self._run_once(
            model, params, warm, tmp_path / "m1", prompts)
        assert info1.disk_misses > 0       # cold start wrote entries
        files1 = {f: (warm / f).read_bytes()
                  for f in os.listdir(warm) if f.endswith(".json")}
        assert files1

        out2, info2, dinfo2 = self._run_once(
            model, params, warm, tmp_path / "m2", prompts)
        assert out2 == out1
        # No re-tracing at all: every program came from disk.
        assert info2.disk_misses == 0
        assert info2.disk_hits + info2.disk_decisions_hits > 0
        assert dinfo2.disk_misses == 0
        # Byte-identical persisted decisions after the restart.
        files2 = {f: (warm / f).read_bytes()
                  for f in os.listdir(warm) if f.endswith(".json")}
        assert files2 == files1
        for raw in files2.values():
            json.loads(raw)  # stays valid JSON

    def test_obs_check_gates_on_cache_hit(self, model_params,
                                          tmp_path):
        """The CI smoke assertion: ``obs report --check
        --expect-cache-hit`` passes on the warm run, fails on cold."""
        model, params = model_params
        warm = tmp_path / "warm"
        prompts = _prompts([6, 10], seed=18)
        self._run_once(model, params, warm, tmp_path / "m1", prompts)
        self._run_once(model, params, warm, tmp_path / "m2", prompts)
        import io
        buf = io.StringIO()
        # Cold run: sites executed but nothing came from disk.
        assert obs_main(["report", str(tmp_path / "m1"), "--check",
                         "--expect-cache-hit"], out=buf) == 1
        assert "CHECK FAIL" in buf.getvalue()
        buf = io.StringIO()
        # Warm run: offloaded sites still execute (static accounting)
        # AND the transform cache resolved from disk.
        assert obs_main(["report", str(tmp_path / "m2"), "--check",
                         "--expect-cache-hit"], out=buf) == 0, \
            buf.getvalue()
        assert "CHECK OK" in buf.getvalue()


class TestKVCacheManager:
    def test_reservation_prevents_decode_deadlock(self, model_params):
        model, _ = model_params
        kv = PagedKVCache(model, batch_slots=2, max_len=64,
                          block_size=16, num_blocks=4)
        assert kv.can_reserve(0, prompt_len=30, max_new=30)
        kv.reserve(0, 30, 30)  # books all 4 blocks
        assert not kv.can_reserve(1, prompt_len=4, max_new=4)
        kv.ensure(0, 60)
        kv.release(0)
        assert kv.can_reserve(1, prompt_len=4, max_new=4)

    def test_allocation_is_deterministic(self, model_params):
        model, _ = model_params
        def trace():
            kv = PagedKVCache(model, batch_slots=2, max_len=64,
                              block_size=16)
            kv.ensure(0, 20)
            kv.ensure(1, 40)
            kv.release(0)
            kv.ensure(1, 50)
            kv.ensure(0, 10)
            return kv._table.copy()
        assert (trace() == trace()).all()

    def test_oversized_reservation_is_named(self, model_params):
        model, _ = model_params
        kv = PagedKVCache(model, batch_slots=2, max_len=64,
                          block_size=16, num_blocks=2)
        with pytest.raises(ValueError, match="raise num_blocks"):
            kv.reserve(0, 40, 20)
