"""Telemetry-stack tests: registry, tracer, events, numerics, serve,
the per-site execution hook, the report/export CLI, and the logger."""

import io
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LMConfig
from repro.core import PrecisionPolicy, offload, site_report
from repro.models import Model
from repro.obs import (Logger, MetricsRun, NumericsMonitor, Registry,
                       Tracer, load_runs, read_events, to_chrome)
from repro.obs.cli import main as obs_main
from repro.obs.events import EventSink, json_safe
from repro.serve import Engine, Request


class TestRegistry:
    def test_counter_identity_and_inc(self):
        reg = Registry()
        c = reg.counter("site_exec", site="dot0")
        assert reg.counter("site_exec", site="dot0") is c
        assert reg.counter("site_exec", site="dot1") is not c
        c.inc()
        c.inc(2)
        assert c.value == 3
        with pytest.raises(ValueError, match=">= 0"):
            c.inc(-1)

    def test_gauge_set_add(self):
        g = Registry().gauge("occupancy")
        g.set(3)
        g.add(-1)
        assert g.value == 2.0

    def test_histogram_stats_and_buckets(self):
        h = Registry().histogram("lat_s")
        for v in (5e-7, 2.0, 5000.0):
            h.observe(v)
        snap = h.snapshot()
        assert snap["count"] == 3
        assert snap["min"] == 5e-7 and snap["max"] == 5000.0
        assert snap["mean"] == pytest.approx(snap["sum"] / 3)
        buckets = dict((str(b), c) for b, c in snap["buckets"])
        assert buckets["1e-06"] == 1     # 5e-7 <= 1e-6
        assert buckets["10.0"] == 1      # 2.0 in (1, 10]
        assert buckets["inf"] == 1       # 5000 beyond the last decade
        assert sum(c for _, c in snap["buckets"]) == 3

    def test_histogram_quantiles(self):
        h = Registry().histogram("lat_s")
        assert h.quantile(0.5) is None  # empty
        for v in (0.01, 0.02, 0.03, 0.04, 9.0):
            h.observe(v)
        p50, p95, p99 = (h.quantile(q) for q in (0.5, 0.95, 0.99))
        # Estimates are clamped to the observed range and monotone.
        assert 0.01 <= p50 <= p95 <= p99 <= 9.0
        assert p50 < 0.1       # 4 of 5 samples in (0.01, 0.1]
        assert p99 > 1.0       # the tail sample dominates p99
        snap = h.snapshot()
        assert snap["p50"] == pytest.approx(p50)
        assert snap["p95"] == pytest.approx(p95)
        assert snap["p99"] == pytest.approx(p99)
        for bad in (0.0, 1.5, -1.0):
            with pytest.raises(ValueError, match="quantile"):
                h.quantile(bad)

    def test_histogram_quantile_single_value(self):
        h = Registry().histogram("lat_s")
        h.observe(2.5)
        # Clamping pins every quantile to the one observation.
        assert h.quantile(0.5) == 2.5
        assert h.quantile(0.99) == 2.5

    def test_kind_conflict_raises(self):
        reg = Registry()
        reg.counter("x", a="1")
        reg.gauge("x", a="2")  # different labels: fine
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("x", a="1")

    def test_snapshot_is_json_and_sorted(self):
        reg = Registry()
        reg.counter("b").inc()
        reg.gauge("a").set(1)
        snap = reg.snapshot()
        json.dumps(snap)  # must not raise
        assert [s["name"] for s in snap] == ["a", "b"]

    def test_counter_under_jit_callback(self):
        """The intercept hook's shape: a zero-operand debug callback
        inside a jitted program, counts drained by effects_barrier."""
        reg = Registry()
        c = reg.counter("execs")

        @jax.jit
        def f(x):
            jax.debug.callback(lambda: c.inc())
            return x * 2

        for _ in range(3):
            f(jnp.ones(4))
        jax.effects_barrier()
        assert c.value == 3


class TestTracer:
    def test_span_nesting(self):
        tr = Tracer()
        with tr.span("outer", step=1):
            with tr.span("inner"):
                pass
        inner, outer = tr.events  # children close (and record) first
        assert inner["name"] == "inner" and outer["name"] == "outer"
        assert inner["ts"] >= outer["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
        assert outer["args"] == {"step": 1}

    def test_exception_flags_error_and_reraises(self):
        tr = Tracer()
        with pytest.raises(RuntimeError):
            with tr.span("boom"):
                raise RuntimeError("x")
        assert tr.events[0]["args"]["error"] is True

    def test_streams_to_sink(self, tmp_path):
        sink = EventSink(tmp_path / "ev.jsonl")
        tr = Tracer(sink=sink)
        with tr.span("s"):
            pass
        sink.close()
        assert tr.events == []  # streamed, not retained
        events = read_events(tmp_path / "ev.jsonl")
        assert [e["type"] for e in events] == ["span"]

    def test_chrome_trace_schema(self):
        tr = Tracer()
        with tr.span("work", k=1):
            pass
        doc = to_chrome(tr.events + [{"type": "step"}])  # non-spans ok
        json.dumps(doc)
        assert doc["displayTimeUnit"] == "ms"
        meta, ev = doc["traceEvents"]
        assert meta["ph"] == "M" and meta["name"] == "process_name"
        assert ev["ph"] == "X" and ev["pid"] == 1
        assert ev["name"] == "work" and ev["args"] == {"k": 1}
        assert isinstance(ev["ts"], float) and ev["dur"] >= 0.0

    def test_chrome_trace_keeps_error_flag(self):
        tr = Tracer()
        with pytest.raises(ValueError):
            with tr.span("load_ckpt", step=3):
                raise ValueError("corrupt")
        doc = to_chrome(tr.events)
        json.dumps(doc)
        (ev,) = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        # The error marker survives export so the viewer can flag it.
        assert ev["args"] == {"step": 3, "error": True}

    def test_chrome_trace_concurrent_spans(self):
        import threading

        tr = Tracer()
        gate = threading.Barrier(2)

        def work(name):
            with tr.span(name):
                gate.wait()      # both spans provably overlap
                with tr.span(f"{name}/inner"):
                    pass

        threads = [threading.Thread(target=work, args=(n,))
                   for n in ("prefill", "decode")]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        doc = to_chrome(tr.events)
        json.dumps(doc)
        spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert {s["name"] for s in spans} == {
            "prefill", "decode", "prefill/inner", "decode/inner"}
        # Each thread keeps its own lane: the viewer must not stack
        # overlapping spans from different threads on one tid.
        tids = {s["name"]: s["tid"] for s in spans}
        assert tids["prefill"] != tids["decode"]
        assert tids["prefill"] == tids["prefill/inner"]
        assert tids["decode"] == tids["decode/inner"]
        for s in spans:
            assert isinstance(s["tid"], int)


class TestEvents:
    def test_json_safe_coerces_numpy(self):
        out = json_safe({"a": np.float32(1.5), "b": np.arange(2),
                         "c": (1, 2), "d": jnp.float32})
        json.dumps(out)
        assert out == {"a": 1.5, "b": [0, 1], "c": [1, 2],
                       "d": str(jnp.float32)}

    def test_run_id_allocation(self, tmp_path):
        with MetricsRun(tmp_path) as r0:
            pass
        with MetricsRun(tmp_path) as r1:
            pass
        assert (r0.run_id, r1.run_id) == ("0000", "0001")
        assert sorted(load_runs(tmp_path)) == ["0000", "0001"]

    def test_site_event_handler_counts_and_declares_once(self, tmp_path):
        run = MetricsRun(tmp_path)
        handler = run.site_event_handler()
        for _ in range(3):
            handler({"site": "dot0", "backend": "fp64_int8_4"})
        handler({"site": "scan0/dot1"})
        run.close()
        events = load_runs(tmp_path)[run.run_id]
        execs = [e for e in events if e["type"] == "site_exec"]
        assert [e["site"] for e in execs] == ["dot0", "scan0/dot1"]
        counters = {(e["labels"]["site"]): e["value"]
                    for e in events if e["type"] == "metric"
                    and e["name"] == "site_exec"}
        assert counters == {"dot0": 3, "scan0/dot1": 1}
        types = [e["type"] for e in events]
        assert types[0] == "run_start" and types[-1] == "run_end"

    def test_read_events_skips_torn_line(self, tmp_path):
        path = tmp_path / "events-0000.jsonl"
        path.write_text('{"t": 1, "type": "step", "loss": 2.0}\n'
                        '{"t": 2, "type": "ru')  # killed mid-write
        events = read_events(path)
        assert len(events) == 1 and events[0]["loss"] == 2.0
        assert events.dropped == 1

    def test_read_events_counts_all_torn_lines(self, tmp_path):
        path = tmp_path / "events-0000.jsonl"
        path.write_text('{"type": "step", "loss": 2.0}\n'
                        'not json at all\n'
                        '[1, 2, 3]\n'             # parseable non-dict
                        '{"type": "step", "loss": 1.0}\n')
        events = read_events(path)
        assert [e["loss"] for e in events] == [2.0, 1.0]
        assert events.dropped == 2

    def test_site_decl_carries_tile_choice(self, tmp_path):
        # Pallas-family sites declare the analytic tile model's pick;
        # jnp-family sites declare tiles=None.
        def f(a, b):
            return jnp.sum(a @ b)

        a = jnp.ones((128, 128), jnp.float32)
        for backend, has_tiles in (("pallas_int8", True),
                                   ("fp64_int8", False)):
            pol = PrecisionPolicy(backend=backend, default_splits=4,
                                  min_dim=64)
            sites = site_report(f, pol)(a, a)
            with MetricsRun(tmp_path / backend) as run:
                run.declare_sites(sites)
            events = load_runs(tmp_path / backend)[run.run_id]
            (decl,) = [e for e in events if e["type"] == "site_decl"]
            if has_tiles:
                assert set(decl["tiles"]) == {"block_m", "block_n",
                                              "block_k", "pairs",
                                              "schedule"}
                assert decl["tiles"]["schedule"] == "ordered"
            else:
                assert decl["tiles"] is None


class TestOnSiteEvent:
    """The intercept hook: offload(..., on_site_event=...)."""

    def test_scan_counts_per_iteration(self):
        counts = {}

        def handler(p):
            counts[p["site"]] = counts.get(p["site"], 0) + 1

        def f(c, xs):
            def body(c, x):
                return c @ x, jnp.sum(c)
            return jax.lax.scan(body, c, xs)

        c = jnp.ones((128, 128), jnp.float32)
        xs = jnp.ones((3, 128, 128), jnp.float32)
        pol = PrecisionPolicy(backend="fp64_int8", default_splits=2,
                              min_dim=64)
        wrapped = offload(f, pol, on_site_event=handler)
        wrapped(c, xs)
        jax.effects_barrier()
        # Forward (no AD): one firing per scan iteration, exactly.
        assert counts == {"scan0/dot0": 3}
        payloadless = wrapped.sites(c, xs)
        assert [s.name for s in payloadless] == ["scan0/dot0"]

    def test_payload_carries_static_site_facts(self):
        seen = []

        def f(a, b):
            return jnp.sum(a @ b)

        a = jnp.ones((128, 96), jnp.float32)
        b = jnp.ones((96, 128), jnp.float32)
        pol = PrecisionPolicy(backend="fp64_int8", default_splits=3,
                              min_dim=64)
        offload(f, pol, on_site_event=seen.append)(a, b)
        jax.effects_barrier()
        (p,) = seen
        assert p["site"] == "dot0" and p["splits"] == 3
        assert p["backend"] == "fp64_int8"
        assert list(p["lhs_shape"]) == [128, 96] and p["k"] == 96
        assert p["dtype"] == "float32" and p["flops"] > 0

    def test_non_offloaded_sites_do_not_fire(self):
        seen = []

        def f(a, b):
            return jnp.sum(a @ b)

        a = jnp.ones((32, 32), jnp.float32)
        offload(f, PrecisionPolicy(min_dim=64),
                on_site_event=seen.append)(a, a)
        jax.effects_barrier()
        assert seen == []

    def test_fires_under_external_grad(self):
        """Zero-operand callbacks survive differentiation (operand-
        carrying ones are dropped by partial-eval): >= 1 per site."""
        counts = {}

        def handler(p):
            counts[p["site"]] = counts.get(p["site"], 0) + 1

        def f(a, b):
            return jnp.sum(jnp.tanh(a @ b))

        a = jnp.ones((128, 128), jnp.float32) * 0.01
        pol = PrecisionPolicy(backend="fp64_int8", default_splits=2,
                              min_dim=64)
        g = jax.grad(offload(f, pol, on_site_event=handler))(a, a)
        jax.effects_barrier()
        assert g.shape == (128, 128)
        assert counts.get("dot0", 0) >= 1


class TestNumericsMonitor:
    def _fn(self, a, b):
        return jnp.sum(a @ b)

    @pytest.fixture(scope="class")
    def operands(self):
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
        return a, b

    def test_healthy_no_drift(self, operands):
        a, b = operands
        pol = PrecisionPolicy(backend="fp64_int8", default_splits=6,
                              min_dim=64)
        mon = NumericsMonitor(self._fn, policy=pol, budget=1e-3)
        report = mon.check(0, a, b)
        assert report.site == "dot0" and report.splits == 6
        assert 0 < report.realized_rel < 1e-3
        assert report.drift is False

    def test_stale_plan_drifts_and_records(self, operands, tmp_path):
        a, b = operands
        # Deliberately under-split with an unmeetable budget: the
        # realized error must breach it -> drift.
        pol = PrecisionPolicy(backend="fp64_int8", default_splits=1,
                              min_dim=64)
        run = MetricsRun(tmp_path)
        stream = io.StringIO()
        mon = NumericsMonitor(self._fn, policy=pol, budget=1e-9,
                              registry=run.registry, sink=run.sink,
                              log=Logger("numerics", stream=stream))
        report = mon.check(7, a, b)
        assert report.drift is True
        assert report.realized_rel > 1e-9
        assert "WARNING: numerics drift at step 7" in stream.getvalue()
        assert "re-tune" in stream.getvalue()
        gauge = run.registry.gauge("numerics_realized_rel",
                                   site="dot0")
        assert gauge.value == pytest.approx(report.realized_rel)
        assert run.registry.counter("numerics_drift",
                                    site="dot0").value == 1
        run.close()
        events = load_runs(tmp_path)[run.run_id]
        (num,) = [e for e in events if e["type"] == "numerics"]
        assert num["step"] == 7 and num["drift"] is True

    def test_probe_never_perturbs_output(self, operands):
        a, b = operands
        pol = PrecisionPolicy(backend="fp64_int8", default_splits=1,
                              min_dim=64)
        mon = NumericsMonitor(self._fn, policy=pol, budget=1e-9)
        native = float(self._fn(a, b))
        probed = float(mon._wrapped(a, b))
        assert probed == pytest.approx(native, rel=1e-6)

    def test_maybe_check_period(self, operands):
        a, b = operands
        pol = PrecisionPolicy(backend="fp64_int8", default_splits=4,
                              min_dim=64)
        mon = NumericsMonitor(self._fn, policy=pol, budget=1.0,
                              every=3)
        assert mon.maybe_check(1, a, b) is None
        assert mon.maybe_check(2, a, b) is None
        assert mon.maybe_check(3, a, b) is not None
        mon.every = 0
        assert mon.maybe_check(3, a, b) is None

    def test_requires_plan_or_policy(self):
        with pytest.raises(ValueError, match="plan or a policy"):
            NumericsMonitor(self._fn)


SMALL = LMConfig(name="test_obs_serve", vocab_size=128, num_layers=1,
                 d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
                 d_ff=128)


class TestServeMetrics:
    @pytest.fixture(scope="class")
    def model_params(self):
        model = Model(SMALL)
        params = model.init_params(jax.random.PRNGKey(0))
        return model, params

    def test_per_request_metrics(self, model_params, tmp_path):
        model, params = model_params
        rng = np.random.default_rng(0)
        prompts = [[int(t) for t in rng.integers(1, 128, n)]
                   for n in (3, 7, 12)]
        run = MetricsRun(tmp_path)
        eng = Engine(model, params, batch_slots=2, max_len=64,
                     metrics=run)
        done = eng.run([Request(prompt=p, max_new_tokens=5)
                        for p in prompts])
        run.close()
        assert all(len(r.out) == 5 for r in done)
        events = load_runs(tmp_path)[run.run_id]
        reqs = [e for e in events if e["type"] == "request"]
        assert len(reqs) == 3
        by_prompt = {e["prompt_len"]: e for e in reqs}
        assert sorted(by_prompt) == [3, 7, 12]
        for ev in reqs:
            # The first token comes from prefill, every further token
            # from one decode tick: ticks == new_tokens - 1 exactly.
            assert ev["new_tokens"] == 5
            assert ev["decode_ticks"] == 4
            assert ev["ttft_s"] is not None and ev["ttft_s"] >= 0
            assert ev["admission_wait_s"] >= 0
            assert ev["prefill_s"] > 0
            assert ev["tokens_per_s"] > 0
        tokens = [e for e in events if e["type"] == "metric"
                  and e["name"] == "serve_tokens"]
        assert tokens[0]["value"] == 15
        occ = [e for e in events if e["type"] == "metric"
               and e["name"] == "serve_slot_occupancy"]
        assert occ[0]["value"] == 0  # drained at run end
        ttft = [e for e in events if e["type"] == "metric"
                and e["name"] == "serve_ttft_s"]
        assert ttft[0]["count"] == 3
        spans = {e["name"] for e in events if e["type"] == "span"}
        assert {"prefill", "decode_tick"} <= spans

    def test_metrics_off_is_untouched(self, model_params):
        model, params = model_params
        eng = Engine(model, params, batch_slots=1, max_len=64)
        (done,) = eng.run([Request(prompt=[1, 2, 3],
                                   max_new_tokens=3)])
        assert len(done.out) == 3


def _seed_run(tmp_path, with_execs=True):
    """A metrics dir with real Site declarations (+ optional execs)."""

    def f(a, b):
        return jnp.sum(jnp.tanh(a @ b) @ b)

    a = jnp.ones((128, 128), jnp.float32)
    pol = PrecisionPolicy(backend="fp64_int8", default_splits=4,
                          min_dim=64)
    sites = site_report(f, pol)(a, a)
    run = MetricsRun(tmp_path)
    run.declare_sites(sites)
    if with_execs:
        handler = run.site_event_handler()
        for s in sites:
            if s.offloaded:
                handler({"site": s.name})
    run.event("step", step=1, loss=3.5, ms=12.0, int8_gemms=20)
    run.event("numerics", step=1, site="dot0", splits=4,
              realized_rel=1.5e-6, budget=3.8e-6, drift=False)
    with run.tracer.span("train_step", step=1):
        pass
    run.close()
    return run.run_id, sites


class TestCli:
    def test_report_tables(self, tmp_path):
        run_id, sites = _seed_run(tmp_path)
        out = io.StringIO()
        rc = obs_main(["report", str(tmp_path)], out=out)
        text = out.getvalue()
        assert rc == 0
        assert f"run {run_id}:" in text
        for s in sites:
            assert s.name in text
        assert "int8_gemms/step" in text
        assert "train_step" in text
        assert "1.500e-06" in text  # realized_rel column

    def test_check_passes_with_execs(self, tmp_path):
        _seed_run(tmp_path)
        out = io.StringIO()
        assert obs_main(["report", str(tmp_path), "--check"],
                        out=out) == 0
        assert "CHECK OK" in out.getvalue()

    def test_check_fails_without_execs(self, tmp_path):
        _seed_run(tmp_path, with_execs=False)
        out = io.StringIO()
        assert obs_main(["report", str(tmp_path), "--check"],
                        out=out) == 1
        assert "recorded no executions" in out.getvalue()

    def test_check_fails_on_run_without_decls(self, tmp_path):
        MetricsRun(tmp_path).close()
        out = io.StringIO()
        assert obs_main(["report", str(tmp_path), "--check"],
                        out=out) == 1
        assert "no site_decl events" in out.getvalue()

    def test_run_selection(self, tmp_path):
        first, _ = _seed_run(tmp_path)
        MetricsRun(tmp_path).close()  # a later, empty run
        out = io.StringIO()
        obs_main(["report", str(tmp_path)], out=out)
        assert "run 0001:" in out.getvalue()  # latest by default
        out = io.StringIO()
        obs_main(["report", str(tmp_path), "--run", first], out=out)
        assert f"run {first}:" in out.getvalue()
        out = io.StringIO()
        obs_main(["report", str(tmp_path), "--all"], out=out)
        assert "run 0000:" in out.getvalue()
        assert "run 0001:" in out.getvalue()
        with pytest.raises(SystemExit):
            obs_main(["report", str(tmp_path), "--run", "9999"],
                     out=io.StringIO())

    def test_report_surfaces_torn_lines(self, tmp_path):
        run_id, _ = _seed_run(tmp_path)
        path = tmp_path / f"events-{run_id}.jsonl"
        with path.open("a") as f:
            f.write('{"type": "ru')  # killed mid-write
        out = io.StringIO()
        assert obs_main(["report", str(tmp_path)], out=out) == 0
        assert "1 torn line(s) dropped" in out.getvalue()

    def test_report_latency_quantile_table(self, tmp_path):
        run = MetricsRun(tmp_path)
        h = run.registry.histogram("serve_ttft_s")
        for v in (0.01, 0.02, 0.03, 4.0):
            h.observe(v)
        run.close()
        out = io.StringIO()
        assert obs_main(["report", str(tmp_path)], out=out) == 0
        text = out.getvalue()
        assert "serve latency quantiles" in text
        assert "p50" in text and "p95" in text and "p99" in text
        assert "serve_ttft_s" in text

    def test_export_writes_chrome_trace(self, tmp_path):
        _seed_run(tmp_path / "metrics")
        target = tmp_path / "trace.json"
        out = io.StringIO()
        rc = obs_main(["export", str(tmp_path / "metrics"),
                       "-o", str(target)], out=out)
        assert rc == 0
        doc = json.loads(target.read_text())
        assert doc["traceEvents"][0]["ph"] == "M"
        spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert [s["name"] for s in spans] == ["train_step"]

    def test_empty_dir_errors(self, tmp_path):
        with pytest.raises(SystemExit, match="no events"):
            obs_main(["report", str(tmp_path)], out=io.StringIO())


class TestLogger:
    def test_level_filtering(self, monkeypatch):
        stream = io.StringIO()
        log = Logger("t", stream=stream)
        monkeypatch.setenv("REPRO_LOG_LEVEL", "WARNING")
        log.info("hidden")
        log.warning("shown")
        monkeypatch.setenv("REPRO_LOG_LEVEL", "DEBUG")
        log.debug("now visible")
        lines = stream.getvalue().splitlines()
        assert lines == ["[t] WARNING: shown", "[t] now visible"]

    def test_info_renders_like_legacy_prints(self):
        stream = io.StringIO()
        Logger("serve", stream=stream).info("OK (3 requests)")
        assert stream.getvalue() == "[serve] OK (3 requests)\n"

    def test_attach_sink_tees(self, tmp_path):
        sink = EventSink(tmp_path / "ev.jsonl")
        log = Logger("train", stream=io.StringIO())
        log.attach_sink(sink)
        log.warning("drift!")
        sink.close()
        (ev,) = read_events(tmp_path / "ev.jsonl")
        assert ev == {**ev, "type": "log", "level": "WARNING",
                      "logger": "train", "msg": "drift!"}

    def test_get_logger_caches(self):
        from repro.obs import get_logger, reset_logger
        a = get_logger("test_obs_cache")
        assert get_logger("test_obs_cache") is a
        b = reset_logger("test_obs_cache")
        assert b is not a
