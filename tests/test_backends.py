"""Backend registry: spec grammar, round-trips, policy binding."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PrecisionPolicy, example_specs, get_backend,
                        register_backend, registered_families)
from repro.core.backends import (AdaptiveBackend, DgemmBackend,
                                 GemmBackend, OzakiBackend,
                                 PallasBackend)


def _gauss(n, seed, dtype=None):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, n)))
    return x.astype(dtype) if dtype else x


class TestRegistry:
    def test_round_trip_every_example_spec(self):
        # The registry's contract: every advertised spec resolves, and
        # the backend remembers the spec it came from.
        for spec in example_specs():
            backend = get_backend(spec)
            assert isinstance(backend, GemmBackend), spec
            assert backend.spec == spec

    def test_families_registered(self):
        fams = registered_families()
        for fam in ("dgemm", "fp64_int8", "pallas_int8", "adaptive"):
            assert fam in fams

    def test_spec_parsing(self):
        assert isinstance(get_backend("dgemm"), DgemmBackend)
        oz = get_backend("fp64_int8_9")
        assert isinstance(oz, OzakiBackend)
        assert oz.pinned_splits == 9
        assert get_backend("fp64_int8").pinned_splits is None
        assert isinstance(get_backend("pallas_int8_4"), PallasBackend)
        ad = get_backend("adaptive:1e-6")
        assert isinstance(ad, AdaptiveBackend)
        assert ad.target_rel == 1e-6

    def test_unknown_and_malformed_specs_rejected(self):
        for bad in ("fp32", "", "dgemm_6", "adaptive_3", "fp64_int8:x"):
            with pytest.raises(ValueError):
                get_backend(bad)

    def test_custom_family_registration(self):
        calls = []

        class Doubling(GemmBackend):
            def matmul(self, a, b, *, out_dtype=None, num_splits=None,
                       site="default"):
                calls.append(site)
                return 2.0 * (a @ b)

        register_backend("doubling",
                         lambda spec, policy, splits, arg:
                         Doubling(spec, policy))
        try:
            backend = get_backend("doubling")
            a = _gauss(8, 0)
            np.testing.assert_allclose(np.asarray(backend(a, a, site="x")),
                                       2.0 * np.asarray(a @ a))
            assert calls == ["x"]
        finally:
            from repro.core import backends as B
            B._FACTORIES.pop("doubling", None)


class TestPolicyBinding:
    def test_pinned_spec_is_authoritative(self):
        pol = PrecisionPolicy(default_splits=3,
                              site_splits={"hot": 9})
        pinned = get_backend("fp64_int8_6", policy=pol)
        assert pinned.resolve_splits(None, "hot") == 6
        assert pinned.resolve_splits(4, "hot") == 6

    def test_unpinned_spec_defers_to_policy(self):
        pol = PrecisionPolicy(default_splits=3, site_splits={"hot": 9})
        free = get_backend("fp64_int8", policy=pol)
        assert free.resolve_splits(None, "hot") == 9
        assert free.resolve_splits(None, "cold") == 3
        assert free.resolve_splits(5, "cold") == 5

    def test_accumulator_binding(self):
        a, b = _gauss(128, 1), _gauss(128, 2)
        ref = a @ b
        denom = jnp.abs(a) @ jnp.abs(b)
        for acc in ("df32", "f64"):
            backend = get_backend(
                "fp64_int8_7", policy=PrecisionPolicy(accumulator=acc))
            c = backend(a, b, out_dtype=jnp.float64)
            err = float(jnp.max(jnp.abs(c - ref) / denom))
            assert err < 1e-11, acc


class TestBackendNumerics:
    def test_dgemm_matches_native(self):
        a, b = _gauss(64, 3), _gauss(64, 4)
        np.testing.assert_array_equal(
            np.asarray(get_backend("dgemm")(a, b)), np.asarray(a @ b))

    def test_ozaki_accuracy_ladder(self):
        a, b = _gauss(128, 5), _gauss(128, 6)
        ref = a @ b
        denom = jnp.abs(a) @ jnp.abs(b)
        errs = []
        for s in (3, 6, 9):
            c = get_backend(f"fp64_int8_{s}")(a, b, out_dtype=jnp.float64)
            errs.append(float(jnp.max(jnp.abs(c - ref) / denom)))
        assert errs[0] > errs[1] > errs[2]

    def test_pallas_matches_jnp_reference(self):
        # interpret-mode kernel vs jnp df32 path: bit-identical by
        # construction (shared slicing + shared TwoSum accumulation).
        a = _gauss(96, 7, jnp.float32)
        b = _gauss(96, 8, jnp.float32)
        pol = PrecisionPolicy(accumulator="df32")
        c_pal = get_backend("pallas_int8_5", policy=pol)(a, b)
        c_jnp = get_backend("fp64_int8_5", policy=pol)(a, b)
        np.testing.assert_array_equal(np.asarray(c_pal),
                                      np.asarray(c_jnp))

    def test_pallas_complex_operands(self):
        rng = np.random.default_rng(9)
        a = jnp.asarray(rng.standard_normal((64, 64))
                        + 1j * rng.standard_normal((64, 64)))
        b = jnp.asarray(rng.standard_normal((64, 64))
                        + 1j * rng.standard_normal((64, 64)))
        c = get_backend("pallas_int8_7")(a, b, out_dtype=jnp.complex128)
        ref = a @ b
        err = float(jnp.max(jnp.abs(c - ref)) / jnp.max(jnp.abs(ref)))
        assert err < 1e-10

    def test_adaptive_probes_and_caches(self):
        backend = get_backend("adaptive:1e-9")
        a, b = _gauss(128, 10), _gauss(128, 11)
        c = backend(a, b, site="tau")
        assert backend.gemm.sites["tau"].err_estimate <= 1e-9
        backend(a, b, site="tau")
        assert backend.gemm.sites["tau"].calls == 2
        ref = a @ b
        denom = jnp.abs(a) @ jnp.abs(b)
        assert float(jnp.max(jnp.abs(c - ref) / denom)) <= 1e-9

    def test_adaptive_traceable(self):
        # Under jit the operands are abstract: the backend must fall
        # back to the a-priori split model instead of probing.
        import jax

        backend = get_backend("adaptive:1e-9")
        a, b = _gauss(128, 12), _gauss(128, 13)
        c = jax.jit(lambda a, b: backend(a, b, site="jit"))(a, b)
        ref = a @ b
        denom = jnp.abs(a) @ jnp.abs(b)
        assert float(jnp.max(jnp.abs(c - ref) / denom)) <= 1e-9
        assert "jit" not in backend.gemm.sites  # no concrete probe ran
