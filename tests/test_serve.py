"""Serve-engine tests: continuous batching equals sequential decoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LMConfig
from repro.core import PrecisionPolicy
from repro.models import Model
from repro.serve import Engine, Request

SMALL = LMConfig(name="test_serve", vocab_size=128, num_layers=1,
                 d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
                 d_ff=128)


@pytest.fixture(scope="module")
def model_params():
    model = Model(SMALL)
    params = model.init_params(jax.random.PRNGKey(0))
    params["lm_head"] = 0.1 * jax.random.normal(
        jax.random.PRNGKey(1), params["lm_head"].shape,
        dtype=jnp.float32)
    return model, params


def _prompts(lengths, seed=0, vocab=SMALL.vocab_size):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(1, vocab, n)] for n in lengths]


class TestEngine:
    def test_mixed_lengths_match_sequential(self, model_params):
        """The satellite criterion: mixed-length prompts in one batch
        produce the same greedy tokens as one-at-a-time decoding."""
        model, params = model_params
        prompts = _prompts([3, 7, 12, 16])
        batched = Engine(model, params, batch_slots=4, max_len=64).run(
            [Request(prompt=p, max_new_tokens=8) for p in prompts])
        for req, prompt in zip(batched, prompts):
            solo, = Engine(model, params, batch_slots=1,
                           max_len=64).run(
                [Request(prompt=prompt, max_new_tokens=8)])
            assert req.out == solo.out, prompt

    def test_queue_longer_than_slots(self, model_params):
        model, params = model_params
        prompts = _prompts([4, 5, 6, 7, 8], seed=1)
        reqs = [Request(prompt=p, max_new_tokens=5) for p in prompts]
        done = Engine(model, params, batch_slots=2, max_len=64).run(reqs)
        assert done is reqs  # returned in submission order
        assert all(r.done and len(r.out) == 5 for r in done)
        # continuous batching must still match sequential decoding
        for req, prompt in zip(done, prompts):
            solo, = Engine(model, params, batch_slots=1,
                           max_len=64).run(
                [Request(prompt=prompt, max_new_tokens=5)])
            assert req.out == solo.out

    def test_eos_evicts_early(self, model_params):
        model, params = model_params
        prompt = _prompts([6], seed=2)[0]
        free, = Engine(model, params, batch_slots=1, max_len=64).run(
            [Request(prompt=prompt, max_new_tokens=20)])
        eos = free.out[0]  # whatever greedy decoding emits first
        eos_model = Model(SMALL.replace(eos_id=eos))
        done, = Engine(eos_model, params, batch_slots=1,
                       max_len=64).run(
            [Request(prompt=prompt, max_new_tokens=20)])
        assert done.out == [eos]

    def test_rejects_oversized_request(self, model_params):
        model, params = model_params
        eng = Engine(model, params, batch_slots=1, max_len=16)
        with pytest.raises(ValueError, match="exceeds max_len"):
            eng.run([Request(prompt=_prompts([12], seed=3)[0],
                             max_new_tokens=8)])
        with pytest.raises(ValueError, match="empty prompt"):
            eng.run([Request(prompt=[], max_new_tokens=2)])
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.run([Request(prompt=[1, 2], max_new_tokens=0)])

    def test_plan_at_startup_matches_unplanned_tokens(self,
                                                      model_params):
        """The engine loads a (train-calibrated) precision plan at
        startup and serves through the offload transform in subset
        mode; at solved split counts the emulation error is far below
        greedy-argmax resolution, so the tokens match exactly."""
        from repro.tune import Calibrator, solve_plan

        model, params = model_params
        batch = jnp.asarray(np.random.default_rng(9).integers(
            1, SMALL.vocab_size, (2, 33)))
        pol = PrecisionPolicy(default_splits=6, min_dim=32)
        cal = Calibrator(model.loss, pol)
        cal.run(params, batch)
        plan = solve_plan(cal.result(), budget=1e-9)

        prompts = _prompts([5, 9, 16, 12], seed=6)
        reqs = lambda: [Request(prompt=p, max_new_tokens=6)  # noqa: E731
                        for p in prompts]
        planned = Engine(model, params, batch_slots=4, max_len=64,
                         plan=plan)
        # The plan actually reaches the transform: the prefill program
        # offloads its projection GEMMs under the plan's size gate.
        psites = planned.prefill_sites(rows=4, width=16)
        assert sum(s.offloaded for s in psites) > 0
        done_plan = planned.run(reqs())
        done_bare = Engine(model, params, batch_slots=4,
                           max_len=64).run(reqs())
        for rp, rb in zip(done_plan, done_bare):
            assert rp.out == rb.out

    def test_slot_reuse_is_clean(self, model_params):
        """A slot's stale cache from a previous occupant must not
        influence the next request (prefill resets length and data)."""
        model, params = model_params
        prompt = _prompts([9], seed=4)[0]
        eng = Engine(model, params, batch_slots=1, max_len=64)
        first, = eng.run([Request(prompt=_prompts([14], seed=5)[0],
                                  max_new_tokens=6)])
        second, = eng.run([Request(prompt=prompt, max_new_tokens=6)])
        solo, = Engine(model, params, batch_slots=1, max_len=64).run(
            [Request(prompt=prompt, max_new_tokens=6)])
        assert second.out == solo.out
