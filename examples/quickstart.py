"""Quickstart: tunable-precision INT8 GEMM emulation + automatic offload.

Runs in ~a minute on CPU:
  1. accuracy-vs-splits sweep of the emulated DGEMM (paper Table 1 trend);
  2. the PEAK-profiler analogue: enumerate BLAS-3 sites of an *unmodified*
     JAX function and offload them at a chosen precision, no code changes,
     then tune a single site through its stable structural name;
  3. adaptive split selection (the paper's proposed dynamic tuning);
  4. the backend registry: every engine behind one spec-string dispatch.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import (AdaptiveGemm, PrecisionPolicy, get_backend,
                        measure_splits, offload, ozaki_matmul,
                        predict_splits, site_report)


def accuracy_sweep():
    print("=== 1. DGEMM emulation accuracy vs split count ===")
    rng = np.random.default_rng(0)
    m = k = n = 512
    a = jnp.asarray(rng.standard_normal((m, k)))
    b = jnp.asarray(rng.standard_normal((k, n)))
    ref = a @ b
    denom = jnp.abs(a) @ jnp.abs(b)
    print(f"{'mode':>14s} {'max rel err':>12s}")
    for s in range(3, 10):
        c = ozaki_matmul(a, b, num_splits=s, accumulator="df32",
                         out_dtype=jnp.float64)
        err = float(jnp.max(jnp.abs(c - ref) / denom))
        print(f"  fp64_int8_{s:<2d} {err:12.3e}")


def automatic_offload():
    print("\n=== 2. Automatic BLAS offload (no code changes) ===")

    def legacy_solver(a, b):  # pretend this is someone else's code
        x = jnp.tanh(a @ b)
        for _ in range(2):
            x = x @ b / jnp.linalg.norm(x)
        return jnp.sum(x)

    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((384, 384)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((384, 384)), jnp.float32)

    policy = PrecisionPolicy(default_splits=6, min_dim=256)
    print("BLAS-3 sites found by the interceptor:")
    for site in site_report(legacy_solver, policy)(a, b):
        print("  ", site)
    ref = legacy_solver(a, b)
    got = offload(legacy_solver, policy)(a, b)
    print(f"native={float(ref):.8f}  emulated={float(got):.8f}  "
          f"rel err={abs(float(got - ref)) / abs(float(ref)):.2e}")

    # The names printed above are stable policy keys: tune one site.
    tuned = PrecisionPolicy(default_splits=6, min_dim=256,
                            site_splits={"dot0": 9})
    print("per-site override (dot0 -> 9 splits):")
    for site in offload(legacy_solver, tuned).sites(a, b):
        print("  ", site)


def backend_registry():
    print("\n=== 4. One registry, every engine (spec strings) ===")
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal((256, 256)))
    b = jnp.asarray(rng.standard_normal((256, 256)))
    ref = a @ b
    denom = jnp.abs(a) @ jnp.abs(b)
    for spec in ("dgemm", "fp64_int8_4", "fp64_int8_8", "adaptive:1e-9"):
        gemm = get_backend(spec)
        err = float(jnp.max(jnp.abs(gemm(a, b, out_dtype=jnp.float64)
                                    - ref) / denom))
        print(f"  {spec:>14s}: max rel err {err:.2e}")


def adaptive():
    print("\n=== 3. Tunable precision: adaptive split selection ===")
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.standard_normal((256, 256)))
    b = jnp.asarray(rng.standard_normal((256, 256)))
    for tol in (1e-4, 1e-8, 1e-12):
        s_pred = predict_splits(a, b, tol)
        s_meas, est = measure_splits(a, b, tol)
        print(f"  target {tol:.0e}: predicted s={s_pred}, "
              f"measured s={s_meas} (err est {est:.2e})")
    gemm = AdaptiveGemm(target_rel=1e-9)
    gemm(a, b, site="tau")
    print(f"  AdaptiveGemm chose s={gemm.sites['tau'].splits} for site 'tau'")


if __name__ == "__main__":
    accuracy_sweep()
    automatic_offload()
    adaptive()
    backend_registry()
