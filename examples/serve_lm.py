"""Serving example: batched requests through the continuous-batching engine.

Loads the newest checkpoint from examples/train_lm.py if present (else
random init), admits a batch of prompts, and decodes greedily — the same
prefill/decode_step programs the decode_32k/long_500k dry-run cells lower
at 512 devices.

  PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.lm import Model
from repro.serve.engine import Engine, Request
from repro.train import checkpoint as CK

from train_lm import REDUCED_100M  # noqa: E402  (same reduced config)


def main():
    cfg = get_config("smollm_360m").replace(**REDUCED_100M)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    ckpt_dir = "runs/ckpt/smollm_360m"
    last = CK.latest_step(ckpt_dir)
    if last is not None:
        print(f"[serve] loading checkpoint step {last}")
        opt_like = None
        try:
            from repro.train.optimizer import AdamW
            opt_like = AdamW().init(params)
            params, _ = CK.restore(ckpt_dir, last, (params, opt_like))
        except Exception as e:
            print(f"[serve] restore failed ({e}); using random init")

    engine = Engine(model, params, batch_slots=4, max_len=512)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size, 16)),
                    max_new_tokens=24) for _ in range(4)]
    done = engine.run(reqs)
    for i, r in enumerate(done):
        print(f"[serve] req{i}: prompt[:4]={r.prompt[:4]} "
              f"-> out[:8]={r.out[:8]} ({len(r.out)} tokens)")
    assert all(len(r.out) > 0 for r in done)
    print("[serve] OK")


if __name__ == "__main__":
    main()
