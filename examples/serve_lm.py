"""Serving example: batched requests through the continuous-batching engine.

Loads the newest checkpoint written by examples/train_lm.py for the
same ``--preset`` if present (else random init), admits a batch of
prompts, and decodes greedily — the same prefill/decode_step programs
the decode_32k/long_500k dry-run cells lower at 512 devices.

  PYTHONPATH=src python examples/serve_lm.py
  PYTHONPATH=src python examples/serve_lm.py --preset tiny
  PYTHONPATH=src python examples/serve_lm.py --temperature 0.8 --seed 7
  PYTHONPATH=src python examples/serve_lm.py --splits 6 \\
      --warm-cache-dir /tmp/serve-cache   # 2nd run skips re-tracing
"""

import argparse

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.lm import Model
from repro.serve.engine import Engine, Request
from repro.train import checkpoint as CK
from repro.train.optimizer import AdamW

from train_lm import PRESETS, ckpt_dir_for  # noqa: E402  (same presets)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="reduced")
    ap.add_argument("--max-new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature "
                         "(0 = greedy, the default)")
    ap.add_argument("--seed", type=int, default=0,
                    help="per-request sampling seed (temperature>0)")
    ap.add_argument("--latency-target-s", type=float, default=None,
                    help="per-request latency target; drives the edf "
                         "scheduler and the latency-slack telemetry")
    ap.add_argument("--scheduler-policy", choices=("fifo", "edf"),
                    default="fifo")
    ap.add_argument("--kv-layout", choices=("paged", "dense"),
                    default="paged")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged KV cache block size in tokens")
    ap.add_argument("--chunk-tokens", type=int, default=None,
                    help="split prefills into pieces of at most this "
                         "many tokens (default: whole prompt)")
    ap.add_argument("--chunk-token-budget", type=int, default=None,
                    help="pack prefill pieces from multiple requests "
                         "into waves of at most this many tokens")
    ap.add_argument("--plan", default="",
                    help="precision-plan JSON: serve the prefill/"
                         "decode GEMMs under the tuned plan")
    ap.add_argument("--splits", type=int, default=0,
                    help="offload every GEMM at this split count "
                         "(a plain PrecisionPolicy; no plan artifact "
                         "needed — handy with --warm-cache-dir)")
    ap.add_argument("--warm-cache-dir", default="",
                    help="persist jaxpr-transform decisions/programs "
                         "here so a restarted server warm-starts "
                         "without re-tracing (needs --plan/--splits)")
    ap.add_argument("--ckpt-dir", default="",
                    help="override the per-preset checkpoint dir")
    ap.add_argument("--metrics-dir", default="",
                    help="telemetry dir (repro.obs JSONL); default: "
                         "<ckpt-dir>/metrics; 'none' disables")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve live Prometheus /metrics on this port "
                         "while the engine runs (0 = ephemeral; the "
                         "chosen port is printed)")
    ap.add_argument("--hold-metrics-s", type=float, default=0.0,
                    help="keep the /metrics endpoint up this many "
                         "seconds after decoding finishes, so an "
                         "external scraper (the CI smoke) can read "
                         "the final counters")
    args = ap.parse_args()

    arch, overrides, _, _ = PRESETS[args.preset]
    cfg = get_config(arch).replace(**overrides)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    ckpt_dir = args.ckpt_dir or ckpt_dir_for(args.preset)
    last = CK.latest_step(ckpt_dir)
    if last is not None:
        print(f"[serve] loading checkpoint step {last}")
        opt_like = AdamW().init(params)
        try:
            params, _ = CK.restore(ckpt_dir, last, (params, opt_like))
        except CK.CheckpointError as e:
            # Only the narrow "checkpoint absent/incompatible" case
            # falls back to random init; anything else is a real bug
            # and propagates.
            print(f"[serve] restore failed ({e}); using random init")

    plan = None
    if args.plan:
        from repro.tune import PrecisionPlan

        plan = PrecisionPlan.load(args.plan)
        print(f"[serve] precision plan {args.plan} "
              f"({plan.fingerprint}, {len(plan.sites)} sites)")
    policy = None
    if args.splits:
        from repro.core import PrecisionPolicy

        policy = PrecisionPolicy(default_splits=args.splits)
    metrics = None
    if args.metrics_dir != "none":
        from repro.obs import MetricsRun

        metrics = MetricsRun(args.metrics_dir
                             or f"{ckpt_dir}/metrics")
    engine = Engine(model, params, batch_slots=4, max_len=512,
                    plan=plan, policy=policy, metrics=metrics,
                    kv_layout=args.kv_layout,
                    block_size=args.block_size,
                    chunk_tokens=args.chunk_tokens,
                    chunk_token_budget=args.chunk_token_budget,
                    warm_cache_dir=args.warm_cache_dir or None,
                    scheduler_policy=args.scheduler_policy,
                    metrics_port=args.metrics_port)
    if engine.metrics_server is not None:
        print(f"[serve] live metrics: "
              f"{engine.metrics_server.url}/metrics", flush=True)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=[int(t) for t in
                            rng.integers(1, cfg.vocab_size, 16)],
                    max_new_tokens=args.max_new_tokens,
                    temperature=args.temperature,
                    seed=args.seed + i,
                    latency_target_s=args.latency_target_s)
            for i in range(4)]
    try:
        done = engine.run(reqs)
        if engine.metrics_server is not None and args.hold_metrics_s:
            import time

            print(f"[serve] holding /metrics open for "
                  f"{args.hold_metrics_s:.0f}s", flush=True)
            time.sleep(args.hold_metrics_s)
    finally:
        engine.close()
        if metrics is not None:
            metrics.close()
    for i, r in enumerate(done):
        print(f"[serve] req{i}: prompt[:4]={r.prompt[:4]} "
              f"-> out[:8]={r.out[:8]} ({len(r.out)} tokens)")
    assert all(len(r.out) > 0 for r in done)
    if metrics is not None:
        print(f"[serve] telemetry: {metrics.sink.path}")
    print("[serve] OK")


if __name__ == "__main__":
    main()
