"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the smollm-360m family at a ~100M reduced width, the deterministic
synthetic data pipeline, AdamW, checkpoint/restart (kill it mid-run and
re-invoke: it resumes), and optionally the paper's technique as the matmul
backend (--backend ozaki_int8_4 trains through INT8-emulated GEMMs with
emulated backward — "tunable precision training").

  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --steps 50 --backend ozaki_int8_4
"""

import argparse
import json

from repro.launch.train import main as train_main

REDUCED_100M = {
    # ~100M params: 12 x d1024 llama-style blocks, 16k vocab
    "num_layers": 12, "d_model": 1024, "num_heads": 16, "num_kv_heads": 8,
    "head_dim": 64, "d_ff": 2816, "vocab_size": 16384,
    "dtype": "float32", "param_dtype": "float32", "remat": False,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--backend", default="")
    args = ap.parse_args()

    argv = ["--arch", "smollm_360m",
            "--overrides", json.dumps(REDUCED_100M),
            "--steps", str(args.steps),
            "--seq-len", str(args.seq_len),
            "--global-batch", str(args.global_batch),
            "--ckpt-every", "100",
            "--log-every", "10"]
    if args.backend:
        argv += ["--backend", args.backend]
    losses = train_main(argv)
    assert losses[-1] < losses[0], "loss did not improve"
    print("[train_lm] OK: loss improved "
          f"{losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
