"""End-to-end driver: train an LM with optionally-emulated GEMMs.

Uses the smollm-360m architecture family at a preset-selected scale,
the deterministic synthetic data pipeline, AdamW, and checkpoint/restart
(kill it mid-run and re-invoke: it resumes).  ``--backend`` routes every
projection/MLP/LM-head matmul of the forward AND backward pass through
the GEMM registry via the automatic offload transform — "tunable
precision training" (``fp64_int8_4`` = 4-slice Ozaki INT8 emulation).

Presets (same architecture, different scale):

  tiny     2 x d128 blocks,  512 vocab  (~0.4M params; CI smoke)
  reduced  6 x d256 blocks, 4096 vocab  (~8M params; CPU default)
  100m    12 x d1024 blocks, 16k vocab  (~158M params; a real run)

  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --steps 4 --backend fp64_int8_4
  PYTHONPATH=src python examples/train_lm.py --steps 4 --backend fp64_int8_4 --preset tiny
"""

import argparse
import json

from repro.launch.train import main as train_main

# preset -> (registered arch name, LMConfig overrides, default
# seq_len, default batch).  The architectures themselves live in
# repro.configs; overrides stay for ad-hoc experiments.
PRESETS = {
    "tiny": ("tiny", {}, 64, 4),
    "reduced": ("reduced", {}, 128, 4),
    "100m": ("reduced_100m", {}, 256, 8),
}


def ckpt_dir_for(preset: str) -> str:
    """Shared with serve_lm.py: one checkpoint lineage per preset."""
    return f"runs/ckpt/lm_{preset}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--preset", choices=sorted(PRESETS), default="reduced")
    ap.add_argument("--seq-len", type=int, default=0,
                    help="0 = preset default")
    ap.add_argument("--global-batch", type=int, default=0,
                    help="0 = preset default")
    ap.add_argument("--backend", default="")
    ap.add_argument("--mesh", default="",
                    help="e.g. 'dp=8' (needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8 "
                         "on CPU)")
    ap.add_argument("--tune", type=int, default=0,
                    help="calibrate N batches, write --plan, exit")
    ap.add_argument("--plan", default="",
                    help="precision-plan JSON (write with --tune, "
                         "train under it without)")
    ap.add_argument("--allow-plan-change", action="store_true",
                    help="adopt a different precision configuration "
                         "on an existing checkpoint lineage")
    ap.add_argument("--ckpt-dir", default="",
                    help="override the per-preset checkpoint dir "
                         "(plans pin training numerics per lineage)")
    args = ap.parse_args()

    arch, overrides, seq_len, batch = PRESETS[args.preset]
    argv = ["--arch", arch,
            "--overrides", json.dumps(overrides),
            "--steps", str(args.steps),
            "--seq-len", str(args.seq_len or seq_len),
            "--global-batch", str(args.global_batch or batch),
            "--ckpt-dir", args.ckpt_dir or ckpt_dir_for(args.preset),
            "--ckpt-every", "100",
            "--log-every", "10"]
    if args.backend:
        argv += ["--backend", args.backend]
    if args.mesh:
        argv += ["--mesh", args.mesh]
    if args.tune:
        argv += ["--tune", str(args.tune)]
    if args.plan:
        argv += ["--plan", args.plan]
    if args.allow_plan_change:
        argv += ["--allow-plan-change"]
    losses = train_main(argv)
    if args.tune:
        print(f"[train_lm] OK: calibrated {args.tune} batch(es); "
              f"plan at {args.plan}")
        return
    if len(losses) >= 2:
        assert losses[-1] < losses[0], "loss did not improve"
        print("[train_lm] OK: loss improved "
              f"{losses[0]:.3f} -> {losses[-1]:.3f}")
    elif losses:
        print(f"[train_lm] OK: trained 1 step (loss {losses[0]:.3f}); "
              "nothing to compare for improvement")
    else:
        print("[train_lm] OK: nothing to train "
              "(checkpoint already at --steps)")


if __name__ == "__main__":
    main()
