"""MuST G(z) reproduction: Table 1 + Figure 1 analogues.

Reproduces the paper's §3.2/§4 study on the LSMS-style workload:
  * max relative error of Re/Im G(z) for fp64_int8_3..9 vs dgemm (Table 1);
  * the per-energy error profile along the contour, showing the isolated
    error peak near the Fermi energy (0.72 Ryd) where G has poles, and the
    exponential decay away from it (Figure 1);
  * contour-integrated observables (total-energy/Fermi analogues)
    converging to the FP64 values by s=5-6.

  PYTHONPATH=src python examples/must_greens_function.py [--n 512]
Writes runs/must/table1.csv and runs/must/fig1.csv.
"""

import argparse
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.apps import must as MU


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=384)
    ap.add_argument("--block", type=int, default=96)
    ap.add_argument("--energies", type=int, default=24)
    ap.add_argument("--splits", type=int, nargs="*",
                    default=[3, 4, 5, 6, 7, 8, 9])
    ap.add_argument("--outdir", default="runs/must")
    args = ap.parse_args()

    cfg = MU.MustConfig(n=args.n, block=args.block,
                        n_energies=args.energies)
    system = MU.build_system(cfg)
    print(f"[must] n={cfg.n} block={cfg.block} energies={cfg.n_energies} "
          f"states near E_f={cfg.fermi}")
    ref = MU.run_contour(cfg, "dgemm", system)
    print(f"[must] dgemm: Etot={ref['etot']:.6f}  Ne={ref['ne']:.6f}")

    outdir = Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    table_rows = ["mode,max_real,max_imag,etot,d_etot,ne,d_ne"]
    fig_rows = ["mode,re_z,im_z,err_real,err_imag"]

    print(f"{'mode':>14s} {'max_real':>10s} {'max_imag':>10s} "
          f"{'Etot':>12s} {'dEtot':>9s}")
    for s in args.splits:
        mode = f"fp64_int8_{s}"
        test = MU.run_contour(cfg, mode, system)
        err = MU.relative_errors(ref, test)
        print(f"{mode:>14s} {err['max_real']:10.2e} {err['max_imag']:10.2e}"
              f" {test['etot']:12.6f} {err['d_etot']:9.2e}")
        table_rows.append(
            f"{mode},{err['max_real']:.3e},{err['max_imag']:.3e},"
            f"{test['etot']:.8f},{err['d_etot']:.3e},"
            f"{test['ne']:.8f},{err['d_ne']:.3e}")
        for z, er, ei in zip(ref["z"], err["per_z_real"],
                             err["per_z_imag"]):
            fig_rows.append(f"{mode},{z.real:.5f},{z.imag:.5f},"
                            f"{er:.3e},{ei:.3e}")
        # Figure-1 pattern: where does the error peak?
        zpk = ref["z"][np.argmax(err["per_z_real"])]
        print(f"{'':>14s} error peak at z = {zpk.real:+.3f}{zpk.imag:+.3f}j"
              f"  (Fermi energy {cfg.fermi})")

    (outdir / "table1.csv").write_text("\n".join(table_rows) + "\n")
    (outdir / "fig1.csv").write_text("\n".join(fig_rows) + "\n")
    print(f"[must] wrote {outdir}/table1.csv and fig1.csv")


if __name__ == "__main__":
    main()
