"""Bench-regression gate: compare a quick-bench CSV against the baseline.

Absolute microseconds are meaningless across machines (a laptop, this
container, a GitHub runner), so the committed baseline stores *ratios*
between a measured row and a native reference row from the same run —
e.g. ``offload_steady_state / gemm_dgemm_256``, the steady-state cost
of an offloaded emulated GEMM relative to the native matmul it
replaces.  A gate fails when the current ratio exceeds the baseline
ratio by more than the tolerance (default 25% — the ISSUE-3 bound on
offload steady-state slowdown).

Usage (what CI runs)::

    PYTHONPATH=src python -m benchmarks.run --quick | tee quick-bench.csv
    python -m benchmarks.compare_baseline quick-bench.csv

Refresh the baseline after an intentional perf change with::

    python -m benchmarks.compare_baseline quick-bench.csv --update
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_BASELINE = Path(__file__).parent / "baseline_quick.json"


def parse_csv(path):
    """CSV rows ``name,us_per_call,derived`` -> ``(times, derived)``.

    ``times`` maps row name to microseconds; ``derived`` maps row name
    to the parsed ``key=value`` pairs of the third column (values kept
    as strings), so gates can check semantic fields like
    ``offloaded_sites`` and not just wall time.
    """
    times, derived = {}, {}
    for line in Path(path).read_text().splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) < 2 or parts[0] == "name":
            continue
        try:
            times[parts[0]] = float(parts[1])
        except ValueError:
            continue
        if len(parts) == 3:
            derived[parts[0]] = dict(
                kv.split("=", 1) for kv in parts[2].split(";")
                if "=" in kv)
    return times, derived


def _is_skip_row(name: str, derived: dict) -> bool:
    """A row a benchmark degraded to instead of failing outright
    (``xxx,0,skipped=...`` or an explicit ``*_skipped`` name)."""
    return "skipped" in derived.get(name, {}) or name.endswith("_skipped")


def evaluate(rows: dict, baseline: dict, derived: dict | None = None):
    """Returns (failures, report_lines); failures empty = gate passes.

    Every malformed/degraded input — a missing or zero or skip-row
    reference, a non-numeric derived field, a gate or check entry
    without its keys — produces a *named* failure line instead of an
    uncaught ``ZeroDivisionError``/``KeyError``, so a degraded bench
    run fails CI with a message that says which gate and why.
    """
    failures, report = [], []
    derived = derived or {}
    for name in baseline.get("required_rows", []):
        if name not in rows:
            failures.append(f"required row {name!r} missing from CSV "
                            "(benchmark failed or was renamed)")
    for check in baseline.get("derived_checks", []):
        row, key = check.get("row"), check.get("key")
        if row is None or key is None or "min" not in check:
            failures.append(f"derived check malformed in baseline "
                            f"(needs row/key/min): {check!r}")
            continue
        val = derived.get(row, {}).get(key)
        if val is None:
            failures.append(f"derived check {row}:{key}: field missing")
            continue
        try:
            num = float(val)
        except ValueError:
            failures.append(f"derived check {row}:{key}: value "
                            f"{val!r} is not numeric (degraded bench "
                            "run?)")
            continue
        if num < check["min"]:
            failures.append(
                f"REGRESSION {row}: {key}={val} < min {check['min']} "
                "(sites silently fell back to native?)")
        else:
            report.append(f"ok {row}: {key}={val} >= {check['min']}")
    tol = float(baseline.get("tolerance", 0.25))
    for gate in baseline.get("gates", []):
        metric, ref = gate.get("metric"), gate.get("reference")
        if metric is None or ref is None or "max_ratio" not in gate:
            failures.append(f"gate malformed in baseline (needs "
                            f"metric/reference/max_ratio): {gate!r}")
            continue
        if metric not in rows or ref not in rows:
            failures.append(f"gate {metric}/{ref}: row missing")
            continue
        skipped = [n for n in (metric, ref) if _is_skip_row(n, derived)]
        if skipped:
            # Surface the benchmark's own skip_reason so the CI log
            # explains WHY the row degraded, not just that it did.
            reasons = "; ".join(
                f"{n}: {derived.get(n, {}).get('skip_reason', 'no skip_reason recorded')}"
                for n in skipped)
            failures.append(
                f"gate {metric}/{ref}: {', '.join(skipped)} is a skip "
                "row from a degraded bench run — no timing to compare "
                f"({reasons})")
            continue
        if rows[ref] <= 0:
            failures.append(f"gate {metric}/{ref}: reference is 0")
            continue
        ratio = rows[metric] / rows[ref]
        # A gate may carry its own tolerance (noisy comparisons like
        # overlapped-vs-blocking step time on a 1-core runner need a
        # wider band than the 25% offload-slowdown bound).
        gate_tol = float(gate.get("tolerance", tol))
        limit = gate["max_ratio"] * (1.0 + gate_tol)
        line = (f"{metric}/{ref}: ratio {ratio:.2f} "
                f"(baseline {gate['max_ratio']:.2f}, limit {limit:.2f})")
        if ratio > limit:
            failures.append(f"REGRESSION {line}")
        else:
            report.append(f"ok {line}")
    return failures, report


def update(rows: dict, baseline: dict,
           derived: dict | None = None) -> dict:
    """Rewrite gate ratios from ``rows``; refuses incomplete CSVs so a
    partially-failed run can never bake bogus ratios into the baseline."""
    derived = derived or {}
    for gate in baseline.get("gates", []):
        if gate.get("metric") is None or gate.get("reference") is None:
            raise SystemExit(f"[bench-gate] cannot --update: gate "
                             f"malformed in baseline: {gate!r}")
        for name in (gate["metric"], gate["reference"]):
            if name not in rows:
                raise SystemExit(
                    f"[bench-gate] cannot --update: row {name!r} "
                    "missing from CSV (did its benchmark fail?)")
            if _is_skip_row(name, derived):
                raise SystemExit(
                    f"[bench-gate] cannot --update: row {name!r} is a "
                    "skip row from a degraded bench run")
        if rows[gate["reference"]] <= 0:
            raise SystemExit(
                f"[bench-gate] cannot --update: reference "
                f"{gate['reference']!r} is 0")
        gate["max_ratio"] = round(rows[gate["metric"]]
                                  / rows[gate["reference"]], 3)
    return baseline


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("csv", help="quick-bench CSV to check")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE))
    ap.add_argument("--tolerance", type=float, default=None,
                    help="override the baseline's tolerance")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline ratios from this CSV")
    args = ap.parse_args(argv)

    rows, derived = parse_csv(args.csv)
    baseline = json.loads(Path(args.baseline).read_text())
    if args.tolerance is not None:
        baseline["tolerance"] = args.tolerance

    if args.update:
        Path(args.baseline).write_text(
            json.dumps(update(rows, baseline, derived), indent=2) + "\n")
        print(f"[bench-gate] baseline updated: {args.baseline}")
        return 0

    failures, report = evaluate(rows, baseline, derived)
    for line in report:
        print(f"[bench-gate] {line}")
    for line in failures:
        print(f"[bench-gate] FAIL {line}", file=sys.stderr)
    if failures:
        return 1
    print("[bench-gate] PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
