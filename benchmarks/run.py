"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Wall-clock numbers are CPU
(this container); TPU-side performance is reported through the roofline
model over the dry-run artifacts (bench_roofline), since the paper's own
performance table (§4: 20.35 vs 62.52 TFLOPS at split 6) is a hardware
measurement we map to the v5e peak model.

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np


def _skip_reason(e) -> str:
    """One CSV-safe clause explaining a degraded row.

    The ``derived`` column is ``;``-separated ``key=value`` pairs on a
    ``,``-separated CSV line, so the reason must not contain either —
    collapse them (and newlines) to spaces and bound the length.
    """
    msg = " ".join(str(e).replace(",", " ").replace(";", " ").split())
    return (msg[:77] + "...") if len(msg) > 80 else (msg or "unknown")


def _timeit(fn, *args, reps=5) -> float:
    jax.block_until_ready(fn(*args))  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        # Block every rep: JAX dispatch is async, so timing only the
        # final block would measure dispatch cost, not compute.
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def bench_table1_must(quick: bool) -> list:
    """Paper Table 1: G(z) accuracy vs split count on the MuST workload."""
    from repro.apps import must as MU

    n = 192 if quick else 384
    cfg = MU.MustConfig(n=n, block=n // 4, n_energies=8 if quick else 16)
    system = MU.build_system(cfg)
    t0 = time.perf_counter()
    ref = MU.run_contour(cfg, "dgemm", system)
    t_ref = (time.perf_counter() - t0) * 1e6 / cfg.n_energies
    rows = [f"must_dgemm_contour_point,{t_ref:.0f},etot={ref['etot']:.6f}"]
    for s in ([3, 5, 7] if quick else [3, 4, 5, 6, 7, 8, 9]):
        t0 = time.perf_counter()
        test = MU.run_contour(cfg, f"fp64_int8_{s}", system)
        dt = (time.perf_counter() - t0) * 1e6 / cfg.n_energies
        err = MU.relative_errors(ref, test)
        rows.append(
            f"must_int8_{s}_contour_point,{dt:.0f},"
            f"max_real={err['max_real']:.3e};max_imag={err['max_imag']:.3e};"
            f"d_etot={err['d_etot']:.3e}")
    return rows


def bench_gemm_accuracy(quick: bool) -> list:
    """Emulation accuracy ladder on a plain DGEMM (Table 1 trend).

    Engines are resolved through the backend registry by spec string —
    the same dispatch path the interceptor and the MuST app use.
    """
    from repro.core import get_backend

    rng = np.random.default_rng(0)
    n = 256 if quick else 512
    a = jnp.asarray(rng.standard_normal((n, n)))
    b = jnp.asarray(rng.standard_normal((n, n)))
    ref = a @ b
    denom = jnp.abs(a) @ jnp.abs(b)
    rows = []
    for spec in [f"fp64_int8_{s}" for s in (3, 5, 7, 9)] + ["dgemm"]:
        backend = get_backend(spec)
        fn = lambda a, b: backend(a, b, out_dtype=jnp.float64)  # noqa: E731
        us = _timeit(jax.jit(fn), a, b)
        err = float(jnp.max(jnp.abs(fn(a, b) - ref) / denom))
        rows.append(f"gemm_{spec}_{n},{us:.0f},maxrel={err:.3e}")
    return rows


def bench_gemm_throughput_model(quick: bool) -> list:
    """Paper §4 analogue: emulated-vs-native throughput at 2048^2.

    GH200 measured: split-6 = 20.35 TFLOPS vs native FP64 = 62.52.
    v5e modeled: native FP64 = 0 (no hardware); emulated split-s
    effective FP64-equivalent TFLOPS = int8_peak / (s(s+1)/2).
    """
    rows = []
    int8_peak = 394e12
    for s in range(3, 10):
        n_gemms = s * (s + 1) / 2
        eff = int8_peak / n_gemms
        gh = "20.35" if s == 6 else "n/a"
        rows.append(f"v5e_fp64eq_tflops_int8_{s},0,"
                    f"modeled={eff/1e12:.2f}TFLOPS;gh200_paper={gh}")
    rows.append("v5e_fp64_native,0,modeled=0TFLOPS(no FP64 unit);"
                "gh200_paper=62.52")
    return rows


def bench_kernel_pallas(quick: bool) -> list:
    """Pallas kernel (interpret) vs pure-jnp path, same split count."""
    from repro.core import ozaki_matmul
    from repro.kernels.tile_model import select_tiles

    rng = np.random.default_rng(1)
    n = 128 if quick else 256
    a = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    us_jnp = _timeit(
        jax.jit(lambda a, b: ozaki_matmul(a, b, num_splits=6)), a, b)
    rows = [f"ozaki6_jnp_{n},{us_jnp:.0f},backend=xla_cpu"]
    # The tile shapes the v2 kernel actually runs with come from the
    # analytic model, not a hard-coded default — report them so a
    # model regression shows up in the row payload, not just timing.
    d = select_tiles(n, n, n, 6, dtype="float32")
    tiles = f"tiles={d.block_m}x{d.block_n}x{d.block_k}"
    try:
        # Pallas interpret mode has no hardware requirements but can be
        # unavailable (no pallas in the jaxlib build, Mosaic-only
        # wheels): skip the row with a reason instead of failing the
        # whole bench.  The registry backend picks interpret mode
        # automatically off-TPU.
        from repro.core import get_backend

        pallas6 = get_backend("pallas_int8_6")
        us_pal = _timeit(lambda a, b: pallas6(a, b), a, b, reps=2)
        rows.append(f"ozaki6_pallas_interpret_{n},{us_pal:.0f},"
                    f"backend=interpret(correctness-only);{tiles}")
    except Exception as e:  # noqa: BLE001 - degrade, don't fail
        rows.append(f"ozaki6_pallas_interpret_{n},0,"
                    f"skipped={type(e).__name__};{tiles};"
                    f"skip_reason={_skip_reason(e)}")
    return rows


def bench_kernel_v2(quick: bool) -> list:
    """v2 split-GEMM data movement: modeled HBM traffic + invocations.

    The v2 kernel's O(s) slice-read claim, made gateable: the analytic
    traffic model (``repro.kernels.tile_model.traffic``) computes the
    slice-array bytes the v1 pair-materializing kernel reads
    (``hbm_bytes_moved_v1``, O(s^2) in the pair count) against what v2
    reads indexing the un-materialized ``(s,m,k)``/``(s,k,n)`` stacks
    (``hbm_bytes_moved``, O(s)), with ``hbm_read_reduction`` their
    ratio — exactly ``(s+1)/2``, i.e. 3.5 at s=6 — and
    ``kernel_invocations`` the pair-schedule length ``s(s+1)/2``.
    Model deriveds are computed even when the kernel itself cannot run
    (no Pallas in the build): compare_baseline's derived checks gate
    the data-movement claim regardless of the timing row's skip state.
    """
    from repro.kernels.tile_model import select_tiles, traffic

    s, n = 6, 128
    d = select_tiles(n, n, n, s, dtype="float32")
    t = traffic(n, n, n, s, d.block_m, d.block_n, d.block_k)
    deriveds = (f"hbm_bytes_moved={t.slice_read_bytes_v2};"
                f"hbm_bytes_moved_v1={t.slice_read_bytes_v1};"
                f"hbm_read_reduction={t.read_reduction:.2f};"
                f"kernel_invocations={d.kernel_invocations};"
                f"pairs={d.pairs};"
                f"tiles={d.block_m}x{d.block_n}x{d.block_k}")
    try:
        from repro.core import ozaki_matmul
        from repro.kernels import ops

        rng = np.random.default_rng(5)
        a = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)

        def v2(a, b):
            return ops.ozaki_matmul(a, b, num_splits=s, interpret=True)

        us = _timeit(v2, a, b, reps=2)
        ref = ozaki_matmul(a, b, num_splits=s)
        bitwise = int(bool(jnp.all(v2(a, b) == ref)))
        rows = [f"kernel_v2_s{s}_{n},{us:.0f},"
                f"{deriveds};bitwise_vs_jnp={bitwise}"]
    except Exception as e:  # noqa: BLE001 - degrade, don't fail
        rows = [f"kernel_v2_s{s}_{n},0,skipped={type(e).__name__};"
                f"{deriveds};skip_reason={_skip_reason(e)}"]
    return rows


def bench_intercept(quick: bool) -> list:
    """Automatic-offload interception cost (trace+rewrite, amortized)."""
    from repro.core import PrecisionPolicy, offload

    rng = np.random.default_rng(2)
    n = 256
    a = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)

    def f(a, b):
        return jnp.sum(jnp.tanh(a @ b) @ b)

    pol = PrecisionPolicy(default_splits=4, min_dim=128)
    t0 = time.perf_counter()
    wrapped = jax.jit(offload(f, pol))
    jax.block_until_ready(wrapped(a, b))
    trace_us = (time.perf_counter() - t0) * 1e6
    us = _timeit(wrapped, a, b)
    return [f"offload_first_call,{trace_us:.0f},includes_trace_and_compile",
            f"offload_steady_state,{us:.0f},per_call"]


def bench_offload_batched(quick: bool) -> list:
    """Batched (rank-3) offload: vmapped contour-point GEMMs.

    A MuST-shaped batch — one GEMM per energy point ``z_k`` of the
    contour, all issued as a single batched ``dot_general`` — exercises
    the transform's reshape/vmap batched path end to end.
    """
    from repro.core import PrecisionPolicy, offload

    rng = np.random.default_rng(3)
    n = 128 if quick else 192
    n_energies = 8 if quick else 16
    h = jnp.asarray(rng.standard_normal((n, n)))
    h = 0.5 * (h + h.T)
    z = jnp.linspace(0.1, 1.3, n_energies) + 0.03j
    mats = z[:, None, None] * jnp.eye(n) - h.astype(jnp.complex128)
    blocks = jnp.asarray(rng.standard_normal((n_energies, n, n)),
                         jnp.complex128)

    def contour_gemms(mats, blocks):
        return jax.vmap(jnp.matmul)(mats, blocks)

    pol = PrecisionPolicy(default_splits=6, min_dim=64,
                          accumulator="f64")
    wrapped = jax.jit(offload(contour_gemms, pol))
    native = jax.jit(contour_gemms)
    ref = native(mats, blocks)
    got = wrapped(mats, blocks)
    err = float(jnp.max(jnp.abs(got - ref)) / jnp.max(jnp.abs(ref)))
    us_emul = _timeit(wrapped, mats, blocks)
    us_nat = _timeit(native, mats, blocks)
    return [
        f"offload_batched_int8_6,{us_emul:.0f},"
        f"batch={n_energies};n={n};maxrel={err:.3e}",
        f"offload_batched_native,{us_nat:.0f},batch={n_energies};n={n}",
    ]


def bench_offload_sharded(quick: bool) -> list:
    """Sharded (shard_map) offload: the multi-device dispatch path.

    A data-parallel GEMM chain under ``shard_map`` over every visible
    device (1 on a plain runner, 8 under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``), offloaded
    through the registry.  The derived column carries the offloaded-
    site count so sharded sites silently falling back to native fail
    the bench-regression gate, not just the timing.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core import PrecisionPolicy, offload
    from repro.shard import build_mesh

    ndev = jax.device_count()
    mesh = build_mesh(f"dp={ndev}")
    n = 192 if quick else 384
    rows_per_shard = 128
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.standard_normal((ndev * rows_per_shard, n)))
    b = jnp.asarray(rng.standard_normal((n, n)))

    def fn(a, b):
        def per_shard(a_s, b_s):
            return jnp.tanh(a_s @ b_s) @ b_s

        return shard_map(per_shard, mesh=mesh,
                         in_specs=(P("dp"), P(None)),
                         out_specs=P("dp"))(a, b)

    pol = PrecisionPolicy(default_splits=6, min_dim=64,
                          accumulator="f64")
    wrapped = offload(fn, pol)
    n_on = sum(s.offloaded for s in wrapped.sites(a, b))
    emul = jax.jit(wrapped)
    native = jax.jit(fn)
    ref = native(a, b)
    err = float(jnp.max(jnp.abs(emul(a, b) - ref))
                / jnp.max(jnp.abs(ref)))
    us_emul = _timeit(emul, a, b)
    us_nat = _timeit(native, a, b)
    return [
        f"offload_sharded_int8_6,{us_emul:.0f},"
        f"devices={ndev};n={n};offloaded_sites={n_on};maxrel={err:.3e}",
        f"offload_sharded_native,{us_nat:.0f},devices={ndev};n={n}",
    ]


def bench_train_2d(quick: bool) -> list:
    """2-D (dp x tp) train step: overlapped vs blocking grad reduce.

    The same sharded train step twice over the largest canonical
    ``dp=N,tp=M`` mesh the visible devices allow (tp=2 when the tiny
    config's head counts divide and >= 2 devices are up, dp = the
    rest): once with the default bucketed all-reduce that XLA can
    overlap with backward GEMMs, once with the ``optimization_barrier``
    reference that forces every gradient to exist before one full-tree
    psum.  The gate ratios overlapped/blocking — overlap must never
    make the step *slower*.  The derived column records the bucket
    count and bytes per psum so a bucketing regression (everything
    collapsing into one bucket, or per-leaf fragmentation) fails the
    gate even when the timing noise hides it.
    """
    from repro.configs import get_config
    from repro.launch.train import build_sharded_train_step
    from repro.models import Model
    from repro.shard import bucket_stats, train_mesh_setup
    from repro.train import AdamW, SyntheticText

    cfg = get_config("tiny")
    ndev = jax.device_count()
    tp = 2 if ndev % 2 == 0 else 1
    dp = max(ndev // tp, 1)
    batch = max(4, dp)
    model, opt = Model(cfg), AdamW(lr=3e-3)
    params = model.init_params(jax.random.PRNGKey(0))
    state = opt.init(params)
    mesh, bsh, (params, state), _ = train_mesh_setup(
        f"dp={dp},tp={tp}", batch, cfg, (params, state))
    data = jax.device_put(
        jnp.asarray(SyntheticText(cfg.vocab_size, 64, batch,
                                  seed=0).batch(0)), bsh)

    # A small bucket so even the tiny tree splits into several psums —
    # the quick bench must exercise the multi-bucket path, not degrade
    # to one all-encompassing psum.
    bucket_bytes = 256 << 10
    n_buckets, sizes = bucket_stats(params, bucket_bytes)
    bpp = int(sum(sizes) / max(n_buckets, 1))

    rows = []
    for mode in ("bucketed", "blocking"):
        step = jax.jit(build_sharded_train_step(
            model, opt, mesh, grad_reduce=mode,
            bucket_bytes=bucket_bytes))
        us = _timeit(step, params, state, data, reps=3)
        tag = "overlapped" if mode == "bucketed" else mode
        rows.append(
            f"train_2d_{tag},{us:.0f},devices={ndev};dp={dp};tp={tp};"
            f"n_buckets={n_buckets};bytes_per_psum={bpp}")
    return rows


def bench_roofline(quick: bool) -> list:
    """§Roofline summary from the dry-run artifacts (if present)."""
    try:
        from repro.analysis.roofline import analyze_cell
    except Exception as e:  # noqa: BLE001 - degrade, don't fail
        return [f"roofline_skipped,0,skipped={type(e).__name__};"
                f"skip_reason=analysis unavailable: {_skip_reason(e)}"]

    rows = []
    outdir = Path("runs/dryrun")
    if not outdir.exists():
        return ["roofline_skipped,0,skipped=1;"
                "skip_reason=no runs/dryrun artifacts"]
    sel = sorted(outdir.glob("*pod16x16.json"))
    if not sel:
        return ["roofline_skipped,0,skipped=1;"
                "skip_reason=no *pod16x16.json artifacts in "
                "runs/dryrun"]
    for j in sel[: 6 if quick else 1000]:
        try:
            r = analyze_cell(j)
            rows.append(
                f"roofline_{r.cell},0,"
                f"compute={r.compute_s:.3f}s;memory={r.memory_s:.3f}s;"
                f"collective={r.collective_s:.3f}s;bound={r.dominant}")
        except Exception as e:
            rows.append(f"roofline_{j.stem},0,parse_error={e!r}")
    return rows


def bench_lm_step(quick: bool) -> list:
    """LM train-step wall time per backend (tiny config, CPU).

    The transformer workload through the same registry dispatch the
    examples use: one full train step (loss forward + backward + AdamW)
    native vs. offloaded at split 4/6.  The derived column carries the
    offloaded-site count so a silent routing regression (sites falling
    back to native) fails the bench-regression gate, not just the
    timing.
    """
    from repro.configs import get_config
    from repro.core import PrecisionPolicy, offload
    from repro.launch.train import build_train_step
    from repro.models import Model
    from repro.train import AdamW, SyntheticText

    cfg = get_config("tiny")
    model = Model(cfg)
    opt = AdamW(lr=3e-3)
    params = model.init_params(jax.random.PRNGKey(0))
    state = opt.init(params)
    batch = jnp.asarray(
        SyntheticText(cfg.vocab_size, 64, 4, seed=0).batch(0))
    step = build_train_step(model, opt)

    us = _timeit(jax.jit(step), params, state, batch, reps=3)
    rows = [f"lm_step_native,{us:.0f},tiny;tokens=256"]
    # The emulated rows run with per-site telemetry ON (the repro.obs
    # site-event hook counting every executed site into a registry) so
    # the existing lm_step_fp64_int8_4/lm_step_native ratio gate also
    # bounds the observability overhead — if the hook ever gets
    # expensive, the bench-regression gate catches it.
    from repro.obs import Registry

    for s in (4,) if quick else (4, 6):
        registry = Registry()
        pol = PrecisionPolicy(backend=f"fp64_int8_{s}",
                              default_splits=s, min_dim=128)
        wrapped = offload(
            step, pol,
            on_site_event=lambda p: registry.counter(
                "site_exec", site=p["site"]).inc())
        n_on = sum(site.offloaded
                   for site in wrapped.sites(params, state, batch))
        us = _timeit(jax.jit(wrapped), params, state, batch, reps=3)
        jax.effects_barrier()  # drain async site-event callbacks
        n_events = int(sum(
            m["value"] for m in registry.snapshot()
            if m["name"] == "site_exec"))
        rows.append(f"lm_step_fp64_int8_{s},{us:.0f},"
                    f"tiny;tokens=256;offloaded_sites={n_on};"
                    f"site_events={n_events}")
    return rows


def bench_tuned_plan(quick: bool) -> list:
    """Tuned precision plan vs uniform splits on the LM train step.

    The paper's pitch, measured: calibrate the train step, solve the
    cost-optimal per-site split assignment, and compare against
    uniform ``fp64_int8_6`` — the tuned plan must issue *fewer* INT8
    GEMMs per step (``saved_int8_gemms`` derived, gated by
    compare_baseline) at equal-or-better end-to-end loss error vs the
    native step (``err_ok`` derived, also gated).
    """
    from repro.configs import get_config
    from repro.core import PrecisionPolicy, offload
    from repro.launch.train import build_train_step
    from repro.models import Model
    from repro.train import AdamW, SyntheticText
    from repro.tune import Calibrator, count_int8_gemms, solve_plan

    cfg = get_config("tiny")
    model = Model(cfg)
    opt = AdamW(lr=3e-3)
    params = model.init_params(jax.random.PRNGKey(0))
    state = opt.init(params)
    data = SyntheticText(cfg.vocab_size, 64, 4, seed=0)
    batch = jnp.asarray(data.batch(0))
    step = build_train_step(model, opt)

    uniform_pol = PrecisionPolicy(backend="fp64_int8",
                                  default_splits=6, min_dim=128)
    cal = Calibrator(step, uniform_pol)
    cal.run(params, state, batch)
    plan = solve_plan(cal.result())
    tuned = offload(step, PrecisionPolicy.from_plan(plan), plan=plan)
    uniform = offload(step, uniform_pol)
    n_tuned = count_int8_gemms(tuned.sites(params, state, batch))
    n_uniform = count_int8_gemms(uniform.sites(params, state, batch))

    def run_steps(fn, n=2):
        p, s = params, state
        for i in range(n):
            p, s, loss = fn(p, s, jnp.asarray(data.batch(i)))
        return float(loss)

    loss_native = run_steps(jax.jit(step))
    d_tuned = abs(run_steps(jax.jit(tuned)) - loss_native)
    d_uniform = abs(run_steps(jax.jit(uniform)) - loss_native)
    # "Equal or better": both emulation errors sit in f32 roundoff
    # noise; the tuned plan passes if it is within noise of uniform.
    err_ok = int(d_tuned <= max(4.0 * d_uniform, 1e-4))
    us = _timeit(jax.jit(tuned), params, state, batch, reps=3)
    return [
        f"tuned_plan_step,{us:.0f},"
        f"int8_gemms_tuned={n_tuned};int8_gemms_uniform={n_uniform};"
        f"saved_int8_gemms={n_uniform - n_tuned};"
        f"loss_delta_tuned={d_tuned:.3e};"
        f"loss_delta_uniform={d_uniform:.3e};err_ok={err_ok}",
    ]


def bench_serve_trace(quick: bool) -> list:
    """Deterministic many-user serve trace: paged vs dense replay.

    The same fixed request trace (seeded ragged prompts, more users
    than slots, per-request max_new) replayed through the paged and
    the dense engine.  ``us_per_call`` is microseconds per *generated
    token* — the gate ratios paged/dense, so the block-table layout
    must sustain the rectangle's tokens/sec.  The paged row's deriveds
    carry the allocation claim (``kv_blocks_hwm`` strictly under
    ``dense_equivalent_blocks``, ``kv_blocks_saved`` >= 1), gated by
    compare_baseline's derived checks.
    """
    from repro.configs import LMConfig
    from repro.models import Model
    from repro.serve import Engine, Request

    cfg = LMConfig(name="bench_serve", vocab_size=128, num_layers=1,
                   d_model=64, num_heads=2, num_kv_heads=1,
                   head_dim=32, d_ff=128)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    n_users = 12 if quick else 32
    rng = np.random.default_rng(2024)
    trace = [([int(t) for t in rng.integers(1, cfg.vocab_size, n)],
              int(m))
             for n, m in zip(rng.integers(4, 40, n_users),
                             rng.integers(4, 9, n_users))]

    rows, out_tokens = [], {}
    for layout in ("paged", "dense"):
        eng = Engine(model, params, batch_slots=4, max_len=64,
                     kv_layout=layout, block_size=16)
        reqs = [Request(prompt=p, max_new_tokens=m) for p, m in trace]
        # Warm the compile caches on a throwaway prefix, then time the
        # full replay.
        Engine(model, params, batch_slots=4, max_len=64,
               kv_layout=layout, block_size=16).run(
            [Request(prompt=p, max_new_tokens=m)
             for p, m in trace[:4]])
        t0 = time.perf_counter()
        done = eng.run(reqs)
        dt = time.perf_counter() - t0
        n_tok = sum(len(r.out) for r in done)
        out_tokens[layout] = [r.out for r in done]
        us_per_tok = dt * 1e6 / max(n_tok, 1)
        derived = (f"users={n_users};tokens={n_tok};"
                   f"tokens_per_s={n_tok / dt:.1f}")
        if layout == "paged":
            st = eng.kv.stats()
            saved = st["dense_equivalent_blocks"] - st["allocated_hwm"]
            derived += (f";kv_blocks_hwm={st['allocated_hwm']};"
                        f"dense_equivalent_blocks="
                        f"{st['dense_equivalent_blocks']};"
                        f"kv_blocks_saved={saved}")
        rows.append(f"serve_trace_{layout},{us_per_tok:.0f},{derived}")
    # The replay is only a fair perf comparison if both layouts emit
    # the same tokens; disagreement voids the row.
    identical = int(out_tokens["paged"] == out_tokens["dense"])
    rows[0] += f";tokens_match_dense={identical}"
    return rows


BENCHES = [bench_gemm_accuracy, bench_gemm_throughput_model,
           bench_kernel_pallas, bench_kernel_v2, bench_intercept,
           bench_offload_batched,
           bench_offload_sharded, bench_train_2d,
           bench_lm_step, bench_tuned_plan, bench_serve_trace,
           bench_table1_must, bench_roofline]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--metrics-dir", default="runs/metrics/bench",
                    help="repro.obs run dir mirroring every CSV row "
                         "as a bench_row event; 'none' disables")
    args, _ = ap.parse_known_args()

    metrics = None
    if args.metrics_dir != "none":
        from repro.obs import MetricsRun

        metrics = MetricsRun(args.metrics_dir)

    def emit(row: str) -> None:
        print(row, flush=True)
        if metrics is None:
            return
        parts = row.split(",", 2)
        try:
            us = float(parts[1]) if len(parts) > 1 else None
        except ValueError:
            us = None
        derived = parts[2] if len(parts) > 2 else ""
        # Mirror the numeric view of the derived payload so obs diff
        # compares values without re-parsing the CSV string.
        from repro.obs.diff import parse_derived

        metrics.event("bench_row", name=parts[0], us_per_call=us,
                      derived=derived,
                      derived_num=parse_derived(derived))

    print("name,us_per_call,derived")
    try:
        for bench in BENCHES:
            if args.only and args.only not in bench.__name__:
                continue
            try:
                for row in bench(args.quick):
                    emit(row)
            except Exception as e:
                emit(f"{bench.__name__}_FAILED,0,{e!r}")
    finally:
        if metrics is not None:
            metrics.close()


if __name__ == "__main__":
    main()
